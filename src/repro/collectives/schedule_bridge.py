"""Glue: BRIDGE schedule synthesis -> collective implementation choice.

`gradient_sync_plan` is the deployment entry point: given the data-parallel
axis size and the gradient payload, it plans the paper's Section 3.6
composite AllReduce under the hardware cost model and returns which
collective implementation the training step should lower (and with which
reconfiguration schedules).  `plan_gradient_sync` is the deprecated legacy
alias (it warns; the README "Deprecated entry points" section documents the
removal path).

It is a documented thin wrapper over the unified planner: it issues one
`repro.planner.PlanRequest` with the composite kind ``ar`` (= RS phase + AG
phase, Rabenseifner decomposition) and maps the `PlanResult` back onto the
legacy `CollectivePlan` shape.  Use `repro.planner` directly for the ranked
alternatives table, constraints (max R / delta budget), objectives, and
plan serialization.

On a static TPU fabric the implementations trade off exactly the terms the
paper's model scores (DESIGN.md Section 3):
  ring  : 2(n-1) unit-offset steps — bandwidth-optimal, latency Omega(n)
  bruck : 2 log2(n) steps at offsets 2^k — latency-optimal, h_k-hop permutes
  psum  : XLA's built-in (typically ring/tree hybrid) as the oracle fallback
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.core import CostModel
from repro.core.cost_model import TPU_V5E
from repro.core.jsonio import FabricKind
from repro.core.schedules import Schedule
from repro.planner import PlanRequest, default_planner, default_strategy_names


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    impl: str                      # 'bruck' | 'ring' | 'psum'
    rs_schedule: Schedule | None
    ag_schedule: Schedule | None
    predicted_time: float
    alternatives: dict[str, float]


def gradient_sync_plan(
    n: int,
    m_bytes: float,
    cm: CostModel | None = None,
    allow: tuple[str, ...] = ("bruck", "ring"),
    fabric: FabricKind = FabricKind.STATIC,
) -> CollectivePlan:
    """Pick the best gradient-allreduce strategy for n devices / m bytes.

    fabric=STATIC (TPU ICI): Bruck is costed with *static* semantics — a
    step at offset 2^k pays h = c = 2^k regardless of schedule (there is no
    OCS to rewire; DESIGN.md S3) and the returned schedules are None so the
    lowering emits one ppermute per Bruck step.  fabric=OCS uses the
    paper's model where reconfigurations reset hop distances, and the
    returned schedules drive the optical fabric.

    Thin wrapper over ``default_planner().plan(PlanRequest(kind='ar', ...))``
    (the shared LRU-cached serving path — a training loop re-planning the
    same gradient sync every step gets an amortized-O(1) answer).
    """
    cm = cm or TPU_V5E
    fabric = FabricKind.coerce(fabric, warn=False)
    names: tuple[str, ...] = ()
    if "bruck" in allow:
        names += default_strategy_names()
    if "ring" in allow:
        names += ("ring",)
    if n <= 1 or not names:
        return CollectivePlan("psum", None, None, 0.0, {})

    res = default_planner().plan(PlanRequest(
        kind="ar", n=n, m_bytes=float(m_bytes), cost_model=cm,
        fabric=fabric, strategies=names))

    alts: dict[str, float] = {}
    for a in res.alternatives:
        t = alts.get(a.impl)
        alts[a.impl] = a.predicted_time if t is None else min(t, a.predicted_time)
    use_schedules = res.impl == "bruck" and fabric == FabricKind.OCS
    return CollectivePlan(
        impl=res.impl,
        rs_schedule=res.rs_schedule if use_schedules else None,
        ag_schedule=res.ag_schedule if use_schedules else None,
        predicted_time=res.predicted_time,
        alternatives=alts,
    )


def plan_gradient_sync(
    n: int,
    m_bytes: float,
    cm: CostModel | None = None,
    allow: tuple[str, ...] = ("bruck", "ring"),
    fabric: str = "static",
) -> CollectivePlan:
    """Deprecated legacy alias of `gradient_sync_plan`.

    .. deprecated::
        Emits a `DeprecationWarning`; call `gradient_sync_plan` (or build a
        `PlanRequest(kind="ar", ...)` directly).  README "Deprecated entry
        points" documents the removal path.
    """
    warnings.warn(
        "collectives.plan_gradient_sync is deprecated; call "
        "collectives.gradient_sync_plan or construct a "
        "PlanRequest(kind='ar', ...) and call repro.planner.Planner.plan "
        "(see README 'Deprecated entry points' for the removal path)",
        DeprecationWarning, stacklevel=2)
    return gradient_sync_plan(n, m_bytes, cm, allow,
                              FabricKind.coerce(fabric, warn=False))
