"""Glue: BRIDGE schedule synthesis -> collective implementation choice.

`plan_gradient_sync` is the deployment entry point: given the data-parallel
axis size and the gradient payload, it runs the paper's Section 3.6 optimizer
under the hardware cost model and returns which collective implementation the
training step should lower (and with which reconfiguration schedules).

On a static TPU fabric the three implementations trade off exactly the terms
the paper's model scores (DESIGN.md Section 3):
  ring  : 2(n-1) unit-offset steps — bandwidth-optimal, latency Omega(n)
  bruck : 2 log2(n) steps at offsets 2^k — latency-optimal, h_k-hop permutes
  psum  : XLA's built-in (typically ring/tree hybrid) as the oracle fallback
"""
from __future__ import annotations

import dataclasses

from repro.core import CostModel, plan
from repro.core.baselines import ring as ring_cost
from repro.core.cost_model import TPU_V5E
from repro.core.schedules import Schedule
from repro.core.simulator import allreduce_time


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    impl: str                      # 'bruck' | 'ring' | 'psum'
    rs_schedule: Schedule | None
    ag_schedule: Schedule | None
    predicted_time: float
    alternatives: dict[str, float]


def plan_gradient_sync(
    n: int,
    m_bytes: float,
    cm: CostModel | None = None,
    allow: tuple[str, ...] = ("bruck", "ring"),
    fabric: str = "static",
) -> CollectivePlan:
    """Pick the best gradient-allreduce strategy for n devices / m bytes.

    fabric='static' (TPU ICI): Bruck is costed with *static* semantics — a
    step at offset 2^k pays h = c = 2^k regardless of schedule (there is no
    OCS to rewire; DESIGN.md S3).  fabric='ocs' uses the paper's model where
    reconfigurations reset hop distances, and the returned schedules drive
    the optical fabric.
    """
    cm = cm or TPU_V5E
    alts: dict[str, float] = {}
    rs = ag = None
    if "bruck" in allow and n > 1:
        if fabric == "ocs":
            rs = plan("rs", n, m_bytes, cm).schedule
            ag = plan("ag", n, m_bytes, cm).schedule
            alts["bruck"] = allreduce_time(rs, ag, m_bytes, cm).total
        else:
            # static fabric: hardware routes each offset-2^k permute; cost it
            # with the static (R=0) model and leave schedules None so the
            # lowering emits one ppermute per Bruck step.
            from repro.core import static_schedule
            alts["bruck"] = allreduce_time(
                static_schedule("rs", n), static_schedule("ag", n),
                m_bytes, cm).total
    if "ring" in allow and n > 1:
        alts["ring"] = ring_cost("ar", n, m_bytes, cm).total
    if not alts:
        return CollectivePlan("psum", None, None, 0.0, {})
    impl = min(alts, key=alts.get)  # type: ignore[arg-type]
    return CollectivePlan(
        impl=impl,
        rs_schedule=rs if impl == "bruck" else None,
        ag_schedule=ag if impl == "bruck" else None,
        predicted_time=alts[impl],
        alternatives=alts,
    )
