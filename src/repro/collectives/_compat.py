"""JAX version compatibility shims (collectives, kernels, launch).

The repo targets a range of JAX releases; everything that relies on an API
whose home or name has moved across versions goes through here.  Current
shims and the drift they triage:

  axis_size              `jax.lax.axis_size` is new; old releases constant-
                         fold `psum(1)` instead.
  shard_map              moved from `jax.experimental.shard_map` to `jax.
                         shard_map`, and `check_rep` was renamed `check_vma`.
  pallas_compiler_params `jax.experimental.pallas.tpu.TPUCompilerParams` was
                         renamed `CompilerParams` (jax 0.6); constructing it
                         through this helper works on both spellings.
  cost_analysis_dict     `Compiled.cost_analysis()` returned a one-element
                         list of dicts on older releases and a flat dict on
                         newer ones; normalize to a dict.
"""
from __future__ import annotations

import jax


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, usable inside shard_map.

    `jax.lax.axis_size` landed in newer releases; on older ones `psum(1)`
    over the axis constant-folds to the same static value at trace time.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return int(jax.lax.psum(1, axis_name))


def shard_map(*args, **kwargs):
    """`jax.shard_map` (new home) or `jax.experimental.shard_map` (old).

    Also translates the `check_vma` kwarg to its pre-rename spelling
    `check_rep` when the installed version only knows the old one.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    try:
        return fn(*args, **kwargs)
    except TypeError:
        if "check_vma" not in kwargs:
            raise
        kwargs = dict(kwargs)
        kwargs["check_rep"] = kwargs.pop("check_vma")
        return fn(*args, **kwargs)


def pcast(x, axis_names, to: str = "varying"):
    """`jax.lax.pcast` (varying-manual-axes casts, new jax) or identity.

    Releases without the vma system (pre-`check_vma` shard_map) treat
    replicated and varying values interchangeably inside shard_map, so the
    cast is a no-op there.
    """
    fn = getattr(jax.lax, "pcast", None)
    if fn is None:
        return x
    return fn(x, axis_names, to=to)


def pallas_compiler_params(**kwargs):
    """TPU Pallas compiler params across the TPUCompilerParams rename.

    jax >= 0.6 spells it `pltpu.CompilerParams`; 0.4/0.5 releases spell it
    `pltpu.TPUCompilerParams` with the same fields (dimension_semantics, ...).
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` normalized to a flat dict.

    Older jax returned `[{...}]` (one entry per computation), newer returns
    `{...}`; either may be None on backends without cost analysis.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return dict(cost) if cost else {}
