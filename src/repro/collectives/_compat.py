"""JAX version compatibility shims for the collectives package.

The repo targets a range of JAX releases; the collectives only rely on two
APIs whose home has moved across versions.
"""
from __future__ import annotations

import jax


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, usable inside shard_map.

    `jax.lax.axis_size` landed in newer releases; on older ones `psum(1)`
    over the axis constant-folds to the same static value at trace time.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return int(jax.lax.psum(1, axis_name))


def shard_map(*args, **kwargs):
    """`jax.shard_map` (new home) or `jax.experimental.shard_map` (old).

    Also translates the `check_vma` kwarg to its pre-rename spelling
    `check_rep` when the installed version only knows the old one.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    try:
        return fn(*args, **kwargs)
    except TypeError:
        if "check_vma" not in kwargs:
            raise
        kwargs = dict(kwargs)
        kwargs["check_rep"] = kwargs.pop("check_vma")
        return fn(*args, **kwargs)
