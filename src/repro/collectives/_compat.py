"""JAX version compatibility shims (collectives, kernels, launch).

The repo targets a range of JAX releases; everything that relies on an API
whose home or name has moved across versions goes through here.  Current
shims and the drift they triage:

  axis_size              `jax.lax.axis_size` is new; old releases constant-
                         fold `psum(1)` instead.
  shard_map              moved from `jax.experimental.shard_map` to `jax.
                         shard_map`, and `check_rep` was renamed `check_vma`.
  pallas_compiler_params `jax.experimental.pallas.tpu.TPUCompilerParams` was
                         renamed `CompilerParams` (jax 0.6); constructing it
                         through this helper works on both spellings.
  cost_analysis_dict     `Compiled.cost_analysis()` returned a one-element
                         list of dicts on older releases and a flat dict on
                         newer ones; normalize to a dict.

Importing this module must never raise: the version probes are all guarded,
so a CPU-only install without jax (or with a jax whose pallas extras are
broken) can still import the pure-NumPy core — `repro.core.batchsim` and the
JAX batch backend consult `HAS_JAX` / `require_jax()` instead of importing
jax at module scope and letting kernels/-style import errors leak into the
core path.  The individual shims raise a clear `ImportError` only when they
are actually *called* without jax installed.
"""
from __future__ import annotations

try:  # the probe itself must never raise at import time
    import jax
    HAS_JAX = True
    JAX_IMPORT_ERROR: Exception | None = None
except Exception as exc:  # pragma: no cover - exercised on jax-less installs
    jax = None  # type: ignore[assignment]
    HAS_JAX = False
    JAX_IMPORT_ERROR = exc


def require_jax(feature: str = "this feature"):
    """Return the jax module, raising an actionable error when absent.

    Every shim below (and the JAX batch backend) funnels through this, so a
    jax-less install fails at the *call* that genuinely needs jax with a
    message naming the feature, never at import time.
    """
    if not HAS_JAX:  # pragma: no cover - exercised on jax-less installs
        raise ImportError(
            f"{feature} requires jax, which failed to import "
            f"({JAX_IMPORT_ERROR!r}); install jax[cpu] or use the NumPy "
            f"backend") from JAX_IMPORT_ERROR
    return jax


def jax_version() -> tuple[int, ...]:
    """Installed jax version as an int tuple, () when jax is absent."""
    if not HAS_JAX:
        return ()
    return tuple(int(p) for p in jax.__version__.split(".")[:3]
                 if p.isdigit())


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, usable inside shard_map.

    `jax.lax.axis_size` landed in newer releases; on older ones `psum(1)`
    over the axis constant-folds to the same static value at trace time.
    """
    jx = require_jax("axis_size")
    fn = getattr(jx.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return int(jx.lax.psum(1, axis_name))


def shard_map(*args, **kwargs):
    """`jax.shard_map` (new home) or `jax.experimental.shard_map` (old).

    Also translates the `check_vma` kwarg to its pre-rename spelling
    `check_rep` when the installed version only knows the old one.
    """
    jx = require_jax("shard_map")
    fn = getattr(jx, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    try:
        return fn(*args, **kwargs)
    except TypeError:
        if "check_vma" not in kwargs:
            raise
        kwargs = dict(kwargs)
        kwargs["check_rep"] = kwargs.pop("check_vma")
        return fn(*args, **kwargs)


def pcast(x, axis_names, to: str = "varying"):
    """`jax.lax.pcast` (varying-manual-axes casts, new jax) or identity.

    Releases without the vma system (pre-`check_vma` shard_map) treat
    replicated and varying values interchangeably inside shard_map, so the
    cast is a no-op there.
    """
    jx = require_jax("pcast")
    fn = getattr(jx.lax, "pcast", None)
    if fn is None:
        return x
    return fn(x, axis_names, to=to)


def pallas_compiler_params(**kwargs):
    """TPU Pallas compiler params across the TPUCompilerParams rename.

    jax >= 0.6 spells it `pltpu.CompilerParams`; 0.4/0.5 releases spell it
    `pltpu.TPUCompilerParams` with the same fields (dimension_semantics, ...).
    """
    require_jax("pallas compiler params")
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` normalized to a flat dict.

    Older jax returned `[{...}]` (one entry per computation), newer returns
    `{...}`; either may be None on backends without cost analysis.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return dict(cost) if cost else {}
