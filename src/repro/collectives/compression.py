"""Gradient compression for the data-parallel sync path.

int8 uniform quantization with *error feedback* (residual accumulation), the
standard trick to keep SGD/Adam convergence while cutting collective bytes by
~4x (Seide et al. 1-bit SGD lineage).  A single scalar max |g| is agreed via
pmax so all devices share one dequantization scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_error_feedback_state(grads):
    """Zero residual pytree matching grads (float32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(v: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.round(v / jnp.maximum(scale, 1e-30))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def compressed_all_reduce(grads, ef_state, axis_name: str):
    """All-reduce-sum a gradient pytree in int8 with error feedback.

    Returns (summed_grads, new_ef_state).  Wire format: int8 payload +
    one f32 scale per tensor (amortized to nothing for large tensors).
    """

    def one(g, e):
        g = g.astype(jnp.float32)
        v = g + e
        scale = jax.lax.pmax(jnp.max(jnp.abs(v)), axis_name) / 127.0
        q = _quantize(v, scale)
        dq = q.astype(jnp.float32) * scale
        new_e = v - dq  # residual kept locally (error feedback)
        # int32 accumulation of the int8 payload across the axis
        total = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32) * scale
        return total, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e, strict=True)]
    summed = tdef.unflatten([o[0] for o in outs])
    new_ef = tdef.unflatten([o[1] for o in outs])
    return summed, new_ef
