"""Bruck-pattern reduce-scatter and all-gather on a JAX device axis.

Both are written in *relative block coordinates* (block r at device i refers
to global block (i + r) mod n for RS, (i - r) mod n for AG) so every device
executes the same static slot schedule — the cyclic symmetry that makes
Bruck's pattern subring-friendly (paper Section 3.1).

Data volumes per step match the paper exactly for power-of-two n:
  RS step k sends n / 2^{k+1} blocks  (m/2, m/4, ... — Section 3.4)
  AG step k sends 2^k blocks          (m/n, 2m/n, ... — Section 3.5)
Arbitrary axis sizes are handled by the remainder rule: a slot only
participates in a step when its target coordinate exists (< n), which is the
slot-level view of the mixed-radix digit classes in `repro.core.bruck`
(empty digit classes are simply skipped).

If a BRIDGE `Schedule` is supplied, each step is lowered as
h_k = offset_k / g ppermutes at the segment's subring link offset g —
store-and-forward along the reusable subring links, exactly the execution the
paper's cost model scores.  Without a schedule, each step is one ppermute at
the step offset (hardware-routed; the TPU default).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bruck import num_steps
from repro.core.schedules import Schedule

from ._compat import axis_size as _axis_size


def _shift_perm(n: int, offset: int) -> list[tuple[int, int]]:
    return [(i, (i + offset) % n) for i in range(n)]


def _permute_hops(val: jax.Array, axis_name: str, n: int, offset: int,
                  link_offset: int) -> jax.Array:
    """Move val by +offset: either one hardware-routed permute or
    offset/link_offset store-and-forward hops along the subring links."""
    if link_offset == offset:
        return jax.lax.ppermute(val, axis_name, _shift_perm(n, offset))
    assert offset % link_offset == 0, (offset, link_offset)
    hops = offset // link_offset
    for _ in range(hops):
        val = jax.lax.ppermute(val, axis_name, _shift_perm(n, link_offset))
    return val


def _link_offsets(schedule: Schedule | None, s: int, offsets: list[int]) -> list[int]:
    if schedule is None:
        return list(offsets)  # one hardware-routed permute per step
    lo = schedule.link_offsets()
    assert len(lo) == s
    return lo


def bruck_reduce_scatter(x: jax.Array, axis_name: str,
                         schedule: Schedule | None = None) -> jax.Array:
    """x: (n, ...) local contributions; returns sum over devices of block i
    at device i (shape x.shape[1:]).  Equivalent to
    psum(x)[axis_index] but in log2(n) Bruck steps."""
    n = _axis_size(axis_name)
    if x.shape[0] != n:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {n}")
    if n == 1:
        return x[0]
    i = jax.lax.axis_index(axis_name)
    s = num_steps(n)
    link = _link_offsets(schedule, s, [2**k for k in range(s)])

    # relative coords: buf[r] = my partial for global block (i + r) mod n
    buf = jnp.take(x, (i + jnp.arange(n)) % n, axis=0)
    for k in range(s):
        off = 2**k
        # active rows with bit k set: r = 2^k (mod 2^{k+1}); receiver merges
        # them at r - 2^k (rows = 0 mod 2^{k+1}).  Restricting to r < n is
        # the arbitrary-n remainder rule (digit classes empty above n).
        send = np.array([r for r in range(n) if r % (2 * off) == off], dtype=np.int32)
        moved = _permute_hops(buf[send], axis_name, n, off, link[k])
        buf = buf.at[send - off].add(moved)
    return buf[0]


def bruck_all_gather(x: jax.Array, axis_name: str,
                     schedule: Schedule | None = None) -> jax.Array:
    """x: (...) local block; returns (n, ...) with row p = device p's block.
    Equivalent to lax.all_gather(x, axis_name) in log2(n) Bruck steps with
    *decreasing* offsets 2^{s-1-k} (paper Section 3.5)."""
    n = _axis_size(axis_name)
    if n == 1:
        return x[None]
    i = jax.lax.axis_index(axis_name)
    s = num_steps(n)
    offsets = [2 ** (s - 1 - k) for k in range(s)]
    link = _link_offsets(schedule, s, offsets)

    # relative coords: buf[r] = block of device (i - r) mod n
    buf = jnp.zeros((n,) + x.shape, x.dtype).at[0].set(x)
    held = [0]
    for k in range(s):
        off = offsets[k]
        # arbitrary-n remainder rule: only slots whose target coordinate
        # exists participate (time-reverse of the RS digit classes).
        send = np.array([r for r in sorted(held) if r + off < n], dtype=np.int32)
        moved = _permute_hops(buf[send], axis_name, n, off, link[k])
        buf = buf.at[send + off].set(moved)
        held = held + [r + off for r in held if r + off < n]
    assert sorted(held) == list(range(n))
    # out[p] = block from device p = buf[(i - p) mod n]
    return jnp.take(buf, (i - jnp.arange(n)) % n, axis=0)
