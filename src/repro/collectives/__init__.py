"""TPU-native BRIDGE collectives: Bruck-pattern log-step collectives on JAX.

Maps the paper's OCS subring communication pattern onto `shard_map` +
`jax.lax.ppermute`.  Each Bruck step k is one collective-permute at ring
offset 2^k; the BRIDGE schedule (from `repro.core.schedules`) selects the
offset decomposition (see DESIGN.md Section 3 for the hardware adaptation).

Importing this package never requires jax: the jax-native submodules load
only when the `._compat` probe succeeded, so a CPU-only install without jax
can still import `repro.collectives._compat` (and through it the pure-NumPy
core, e.g. `repro.core.batchsim` with ``backend="auto"``).  Accessing a
collective by name on a jax-less install raises an actionable ImportError
at the access, not at import time.
"""
from ._compat import HAS_JAX, JAX_IMPORT_ERROR

__all__ = [
    "bruck_all_to_all", "bruck_all_gather", "bruck_reduce_scatter",
    "bridge_all_reduce", "bruck_all_reduce", "ring_all_gather",
    "ring_all_reduce", "ring_reduce_scatter",
    "compressed_all_reduce", "make_error_feedback_state",
    "CollectivePlan", "gradient_sync_plan", "plan_gradient_sync",
]

if HAS_JAX:
    from .allreduce import (bridge_all_reduce, bruck_all_reduce,
                            ring_all_gather, ring_all_reduce,
                            ring_reduce_scatter)
    from .bruck_a2a import bruck_all_to_all
    from .bruck_rs_ag import bruck_all_gather, bruck_reduce_scatter
    from .compression import compressed_all_reduce, make_error_feedback_state
    from .schedule_bridge import (CollectivePlan, gradient_sync_plan,
                                  plan_gradient_sync)
else:  # pragma: no cover - exercised on jax-less installs (subprocess test)
    def __getattr__(name):
        if name in __all__:
            raise ImportError(
                f"repro.collectives.{name} requires jax, which failed to "
                f"import ({JAX_IMPORT_ERROR!r}); the NumPy planning/"
                f"simulation core (repro.core, repro.planner) works without "
                f"it") from JAX_IMPORT_ERROR
        raise AttributeError(
            f"module 'repro.collectives' has no attribute {name!r}")
