"""TPU-native BRIDGE collectives: Bruck-pattern log-step collectives on JAX.

Maps the paper's OCS subring communication pattern onto `shard_map` +
`jax.lax.ppermute`.  Each Bruck step k is one collective-permute at ring
offset 2^k; the BRIDGE schedule (from `repro.core.schedules`) selects the
offset decomposition (see DESIGN.md Section 3 for the hardware adaptation).
"""
from .allreduce import (bridge_all_reduce, bruck_all_reduce, ring_all_gather,
                        ring_all_reduce, ring_reduce_scatter)
from .bruck_a2a import bruck_all_to_all
from .bruck_rs_ag import bruck_all_gather, bruck_reduce_scatter
from .compression import compressed_all_reduce, make_error_feedback_state
from .schedule_bridge import CollectivePlan, plan_gradient_sync

__all__ = [
    "bruck_all_to_all", "bruck_all_gather", "bruck_reduce_scatter",
    "bridge_all_reduce", "bruck_all_reduce", "ring_all_gather",
    "ring_all_reduce", "ring_reduce_scatter",
    "compressed_all_reduce", "make_error_feedback_state",
    "CollectivePlan", "plan_gradient_sync",
]
