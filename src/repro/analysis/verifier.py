"""Static verifier for schedules, tapes, plans, and fabric snapshots.

Checks structural invariants of every planning artifact *without running a
simulator*: each rule re-derives the claimed quantity independently — digit
classes by brute-force enumeration over destinations (not the closed forms
in `core.bruck`), segment gcds and changed-circuit sets from the raw offset
algebra (not the DP tables), boundary ledgers by direct summation — and
reports mismatches as structured `Violation` records.  Rule ids are stable
and catalogued with the paper condition each encodes in docs/invariants.md.

Trust boundaries wired through this module:

  - `repro.planner.Planner` verifies every `PlanResult` before it enters the
    LRU plan cache (`verify_plan`);
  - `repro.workloads.serve.PlanService` audits every `ServedPlan` before it
    is cached and served (`verify_served_plan`);
  - `repro.workloads.online_planner.OnlinePlanner` audits every window DP
    solution — including warm-started suffix re-plans — before committing
    from it (`verify_window_choice`);
  - `benchmarks/verify_gate.py` statically audits every plan implied by the
    committed BENCH_*.json baselines in CI.

All verify_* functions return a list of `Violation`s (empty = clean); they
never raise on bad artifacts.  Schedule- and tape-level verification is
memoized per object, so serving-path audits of repeated schedules are
amortized-O(1).
"""
from __future__ import annotations

import functools
import math
from typing import TYPE_CHECKING, Sequence

from repro.core.batchsim import FabricSnapshot, ScheduleTape, compile_tape
from repro.core.schedules import Schedule, changed_links

from .violations import Violation

if TYPE_CHECKING:  # imported for annotations only: no planner/workloads cycle
    from repro.core.cost_model import CostModel
    from repro.planner.api import PlanResult

KINDS = ("a2a", "rs", "ag")
TRACE_MODES = ("carryover", "cold", "static", "online")

#: relative tolerance for re-derived float ledgers (the re-derivations use
#: the same expression order as the producers, so drift means corruption)
REL_TOL = 1e-9


def _close(a: float, b: float, tol: float = REL_TOL) -> bool:
    return abs(a - b) <= tol * max(1.0, abs(a), abs(b))


def _offset_digit(offset: int, r: int) -> tuple[int, int] | None:
    """Decompose a Bruck message offset as (phase k, digit j) with
    ``offset == j * r**k`` and 1 <= j < r; None when no such form exists."""
    if offset < 1:
        return None
    k, w = 0, 1
    while w * r <= offset:
        w *= r
        k += 1
    j = offset // w
    if j * w != offset or not 1 <= j < r:
        return None
    return k, j


def _expected_structure(kind: str, n: int, r: int) -> list[tuple[int, int, int]]:
    """Expected (offset, k, j) sub-step sequence, by direct enumeration.

    A digit class (k, j) is non-empty iff j * r**k < n; A2A and RS walk
    ascending place values, AG is the exact time-reverse (paper Section 3.5).
    Independent of `core.bruck.step_counts` (which goes through the per-kind
    generators and their closed-form counts).
    """
    s, w = 0, 1
    while w < n:
        w *= r
        s += 1
    fwd = [(j * r**k, k, j)
           for k in range(s) for j in range(1, r) if j * r**k < n]
    return list(reversed(fwd)) if kind == "ag" else fwd


def _brute_count(kind: str, n: int, r: int, k: int, j: int) -> int:
    """Blocks moved by sub-step (k, j), recounted destination by destination
    (the executable definition, not the closed form):

      - a2a: blocks whose relative destination offset has k-th digit j;
      - rs / ag: blocks whose offset is a multiple of r**k with k-th digit j
        (the partial sums forwarded at phase k; AG is reversed RS).
    """
    w = r**k
    if kind == "a2a":
        return sum(1 for d in range(n) if (d // w) % r == j)
    return sum(1 for d in range(n) if d % w == 0 and (d // w) % r == j)


def _conservation(kind: str, n: int, r: int,
                  steps: Sequence[tuple[int, int, int]]) -> list[int]:
    """Destinations the tape's step sequence fails to deliver.

    Chunk conservation over the link-offset algebra: every relative offset
    d in [0, n) must be exactly covered by the digit decomposition the steps
    implement (generalized Lemma 3.2 / Section 3.1 telescoping).

      - a2a: the offsets of the steps matching d's digits must sum to d;
      - rs:  walking the steps in order must drain d's remaining offset to 0;
      - ag:  time-reverse of rs — the reversed sequence must drain d.
    """
    bad = []
    if kind == "a2a":
        for d in range(n):
            moved = sum(off for off, k, j in steps if (d // r**k) % r == j)
            if moved != d:
                bad.append(d)
        return bad
    walk = list(reversed(steps)) if kind == "ag" else list(steps)
    for d in range(n):
        rem = d
        for off, k, j in walk:
            w = r**k
            if rem % w == 0 and (rem // w) % r == j:
                rem -= off
        if rem != 0:
            bad.append(d)
    return bad


# --- tape / schedule level ----------------------------------------------------


@functools.lru_cache(maxsize=4096)
def verify_tape(tape: ScheduleTape) -> tuple[Violation, ...]:
    """All tape-level rules (memoized per tape).  See docs/invariants.md."""
    out: list[Violation] = []
    loc = f"{tape.kind} n={tape.n} r={tape.r}"

    def bad(rule: str, message: str, repro: str = "", where: str = ""):
        out.append(Violation(rule=rule, location=f"{loc}{where}",
                             message=message, repro=repro))

    if tape.kind not in KINDS or tape.n < 2 or tape.r < 2:
        bad("tape/shape", f"invalid header (kind={tape.kind!r}, n={tape.n}, "
            f"r={tape.r})")
        return tuple(out)
    expected = _expected_structure(tape.kind, tape.n, tape.r)
    n, r, S = tape.n, tape.r, len(expected)
    fields = ("offsets", "counts", "g_step", "hops", "boundary",
              "changed_pay", "seg_of")
    lens = {f: len(getattr(tape, f)) for f in fields}
    if tape.S != S or any(ln != S for ln in lens.values()):
        bad("tape/shape", f"sub-step arrays must all have length S={S}, got "
            f"S={tape.S}, {lens}")
        return tuple(out)  # later rules index by step; shape must hold first

    # tape/offset-form + the derived (k, j) of every step
    digits: list[tuple[int, int] | None] = []
    for i, off in enumerate(tape.offsets):
        kj = _offset_digit(off, r)
        if kj is None or off >= n:
            bad("tape/offset-form",
                f"offset {off} is not j*r^k with 1 <= j < r and offset < n",
                where=f" step {i}")
        digits.append(kj)
    if [  # tape/structure: the (offset) sequence itself (order + multiset)
        off for off, _, _ in expected
    ] != list(tape.offsets):
        bad("tape/structure",
            f"step offsets {list(tape.offsets)} != the {tape.kind} digit "
            f"enumeration {[off for off, _, _ in expected]}")

    # tape/counts: brute-force digit-class recount per step
    for i, (cnt, kj) in enumerate(zip(tape.counts, digits, strict=True)):
        if kj is None:
            continue
        want = _brute_count(tape.kind, n, r, *kj)
        if cnt != want:
            bad("tape/counts",
                f"count {cnt} != {want} blocks in digit class (k={kj[0]}, "
                f"j={kj[1]})", where=f" step {i}")

    # tape/conserve: every destination offset exactly covered
    if all(kj is not None for kj in digits):
        steps = [(off, *kj)
                 for off, kj in zip(tape.offsets, digits, strict=True)]
        missed = _conservation(tape.kind, n, r, steps)
        if missed:
            bad("tape/conserve",
                f"destinations {missed[:8]}{'...' if len(missed) > 8 else ''} "
                f"are not exactly covered by the step sequence",
                repro=f"offsets={list(tape.offsets)}")

    # tape/seg: boundary bits <-> segment map consistency
    if tape.boundary[0] not in (0, False):
        bad("tape/seg", "x_0 must be 0: the initial topology is "
            "pre-established before the collective starts")
    seg = [0] * S
    for k in range(1, S):
        seg[k] = seg[k - 1] + (1 if tape.boundary[k] else 0)
    if list(tape.seg_of) != seg:
        bad("tape/seg", f"seg_of {list(tape.seg_of)} != segment map "
            f"{seg} derived from the boundary bits")
    n_seg = seg[-1] + 1
    segments = [(a, b) for a, b in
                zip([k for k in range(S) if seg[k] != seg[k - 1] or k == 0],
                    [k for k in range(S)
                     if k == S - 1 or seg[k + 1] != seg[k]], strict=True)]

    # tape/gcd: per-segment link offset is the gcd of its message offsets
    seg_g = [0] * n_seg
    for si, (a, b) in enumerate(segments):
        g = 0
        for k in range(a, b + 1):
            g = math.gcd(g, tape.offsets[k])
        seg_g[si] = g
        for k in range(a, b + 1):
            if tape.g_step[k] != g:
                bad("tape/gcd",
                    f"link offset {tape.g_step[k]} != gcd {g} of segment "
                    f"{si} offsets {list(tape.offsets[a:b + 1])}",
                    where=f" step {k}")
    if len(tape.seg_g) != n_seg or list(tape.seg_g) != seg_g:
        bad("tape/seg", f"seg_g {list(tape.seg_g)} != per-segment gcds "
            f"{seg_g}")

    # tape/subring: the circuit set u -> u + g the tape claims per step must
    # be a permutation with 1 <= g < n (port-conflict freedom: every ingress
    # port receives exactly one circuit; g = 0 would self-loop, g >= n
    # aliases).  Checked on the *claimed* offsets — the re-derived gcds are
    # in range by construction.
    for k in range(S):
        g = tape.g_step[k]
        if not 1 <= g < n:
            bad("tape/subring",
                f"claimed link offset {g} is outside [1, n): the uniform "
                f"circuit set u -> u+{g} is not a conflict-free subring "
                f"permutation", where=f" step {k}")

    # tape/reach (generalized Lemma 3.2): a step's destination is reachable
    # inside its segment's subring iff the message offset is divisible by
    # the link offset; tape/hops pins the claimed hop counts to offset / g
    for k in range(S):
        g, off = tape.g_step[k], tape.offsets[k]
        if g >= 1 and off % g != 0:
            bad("tape/reach",
                f"offset {off} is not divisible by link offset {g}: the "
                f"destination is unreachable in the subring", where=f" step {k}")
        elif g >= 1 and tape.hops[k] != off // g:
            bad("tape/hops", f"hops {tape.hops[k]} != offset/g = {off // g}",
                where=f" step {k}")
    want_seg_hops = [sum(tape.hops[a:b + 1]) for a, b in segments]
    if len(tape.seg_hops) != n_seg or list(tape.seg_hops) != want_seg_hops:
        bad("tape/seg", f"seg_hops {list(tape.seg_hops)} != per-segment hop "
            f"sums {want_seg_hops}")

    # tape/changed: the sparse-boundary accounting.  changed_pay marks the
    # boundaries that physically rewire circuits; changed_links carries the
    # per-reconfiguration changed-circuit count (uniform subrings: 0 or n)
    for k in range(S):
        want = bool(tape.boundary[k]) and k > 0 and \
            tape.g_step[k] != tape.g_step[k - 1]
        if bool(tape.changed_pay[k]) != want:
            bad("tape/changed",
                f"changed_pay {bool(tape.changed_pay[k])} != {want} "
                f"(boundary={bool(tape.boundary[k])}, g {tape.g_step[k - 1] if k else '-'}"
                f"->{tape.g_step[k]})", where=f" step {k}")
    want_changed = tuple(
        0 if seg_g[i - 1] == seg_g[i] else n for i in range(1, n_seg))
    if tuple(tape.changed_links) != want_changed:
        bad("tape/changed",
            f"changed_links {tuple(tape.changed_links)} != re-derived "
            f"per-boundary circuit diffs {want_changed}")
    return tuple(out)


@functools.lru_cache(maxsize=4096)
def verify_schedule(schedule: Schedule) -> tuple[Violation, ...]:
    """Schedule-level rules + every tape rule on its compiled tape."""
    out: list[Violation] = []
    loc = f"{schedule.kind} n={schedule.n} r={schedule.r}"
    x = schedule.x
    ok_format = True
    if any(v not in (0, 1) for v in x) or (x and x[0] != 0):
        out.append(Violation(
            rule="sch/x-format", location=loc,
            message=f"x must be 0/1 with x_0 = 0, got {list(x)}",
            repro=f"x={list(x)}"))
        ok_format = False
    try:
        expected_len = len(_expected_structure(schedule.kind, schedule.n,
                                               schedule.r))
    except Exception:
        expected_len = -1
    if len(x) != expected_len:
        out.append(Violation(
            rule="sch/x-format", location=loc,
            message=f"schedule length {len(x)} != S={expected_len}"))
        ok_format = False
    if ok_format:
        out.extend(verify_tape(compile_tape(schedule)))
    return tuple(out)


def _paid_reconfigs(schedule: Schedule) -> int:
    """Paid intra-collective reconfigurations, re-derived from raw segment
    gcds (a boundary pays iff the adjacent segments' gcds differ)."""
    gs = [g for g, _ in _segment_offsets(schedule)]
    return sum(1 for a, b in zip(gs, gs[1:], strict=False) if a != b)


def _segment_offsets(schedule: Schedule) -> list[tuple[int, int]]:
    """(gcd, first_step) of every segment, from the raw offset algebra."""
    tape = compile_tape(schedule)
    out, start = [], 0
    for k in range(1, tape.S + 1):
        if k == tape.S or tape.boundary[k]:
            g = 0
            for i in range(start, k):
                g = math.gcd(g, tape.offsets[i])
            out.append((g, start))
            start = k
    return out


def _first_last_g(schedule: Schedule) -> tuple[int, int]:
    segs = _segment_offsets(schedule)
    return segs[0][0], segs[-1][0]


# --- plan level ---------------------------------------------------------------


def _check_schedule_header(out: list[Violation], rule: str, loc: str,
                           sched: Schedule, kind: str, n: int, r: int) -> None:
    if sched.kind != kind or sched.n != n or sched.r != r:
        out.append(Violation(
            rule=rule, location=loc,
            message=f"schedule ({sched.kind}, n={sched.n}, r={sched.r}) does "
                    f"not match the request ({kind}, n={n}, r={r})"))


def verify_plan(res: "PlanResult") -> list[Violation]:
    """Every plan-level rule on one `PlanResult` (see docs/invariants.md)."""
    out: list[Violation] = []
    req = res.request
    loc = f"plan {req.kind} n={req.n} r={req.r} fabric={req.fabric}"

    def bad(rule: str, message: str, repro: str = ""):
        out.append(Violation(rule=rule, location=loc, message=message,
                             repro=repro))

    # plan/kind: winner schedules present and consistent with the request
    schedules: list[Schedule] = []
    if req.kind == "ar":
        if res.schedule is not None:
            bad("plan/kind", "composite 'ar' results carry (rs_schedule, "
                "ag_schedule), not a single schedule")
        if res.impl == "bruck":
            if res.rs_schedule is None or res.ag_schedule is None:
                bad("plan/kind", "bruck 'ar' winner must carry both phase "
                    "schedules")
            else:
                _check_schedule_header(out, "plan/kind", loc,
                                       res.rs_schedule, "rs", req.n, req.r)
                _check_schedule_header(out, "plan/kind", loc,
                                       res.ag_schedule, "ag", req.n, req.r)
                schedules = [res.rs_schedule, res.ag_schedule]
    else:
        if res.rs_schedule is not None or res.ag_schedule is not None:
            bad("plan/kind", f"single-collective {req.kind!r} results must "
                f"not carry ar phase schedules")
        if res.impl == "bruck":
            if res.schedule is None:
                bad("plan/kind", "bruck winner must carry a schedule")
            else:
                _check_schedule_header(out, "plan/kind", loc, res.schedule,
                                       req.kind, req.n, req.r)
                schedules = [res.schedule]
    for sched in schedules:
        out.extend(verify_schedule(sched))

    # plan/budget: reconfiguration caps hold; static fabrics never rewire
    cap = req.effective_max_R()
    R_total = sum(s.R for s in schedules)
    if schedules and cap is not None and R_total > cap:
        bad("plan/budget", f"winner spends R={R_total} > effective cap {cap} "
            f"(max_R={req.max_R}, delta_budget={req.delta_budget})")
    if schedules and req.fabric == "static" and R_total > 0:
        bad("plan/budget", f"static fabric has no OCS to rewire "
            f"mid-collective, winner has R={R_total}")

    # plan/entry: predicted time re-derived as breakdown total + the sparse
    # entry-boundary cost of the inherited fabric state (analytic fabrics
    # only: ocs-sim predictions are simulated completions, not breakdowns)
    if req.fabric != "ocs-sim":
        entry = 0.0
        entry_sched = schedules[0] if schedules else None
        if req.init_g is not None and entry_sched is not None:
            g_first, _ = _first_last_g(entry_sched)
            entry = req.cost_model.delta_sparse(
                changed_links(req.n, req.init_g, g_first), req.overlap)
        want = res.breakdown.total + entry
        if not _close(res.predicted_time, want):
            bad("plan/entry",
                f"predicted_time {res.predicted_time!r} != breakdown total "
                f"+ entry boundary = {want!r}",
                repro=f"total={res.breakdown.total!r} entry={entry!r} "
                      f"init_g={req.init_g}")

    # plan/rank: alternatives sorted best-first and the winner is the head
    alts = res.alternatives
    if not alts:
        bad("plan/rank", "a plan must rank at least its winner")
    else:
        if any(a.score > b.score
               for a, b in zip(alts, alts[1:], strict=False)):
            bad("plan/rank", "alternatives are not sorted by ascending score",
                repro=f"scores={[a.score for a in alts]}")
        if alts[0].strategy != res.strategy or alts[0].impl != res.impl:
            bad("plan/rank",
                f"winner ({res.strategy!r}, {res.impl!r}) != best-ranked "
                f"alternative ({alts[0].strategy!r}, {alts[0].impl!r})")
        if not _close(alts[0].predicted_time, res.predicted_time):
            bad("plan/rank",
                f"winner predicted_time {res.predicted_time!r} != "
                f"best-ranked row's {alts[0].predicted_time!r}")

    # plan/dedup + plan/alt: each schedule is evaluated once; row R == sum(x)
    seen_x = set()
    for i, alt in enumerate(alts):
        if alt.x is None:
            continue
        if alt.x in seen_x:
            bad("plan/dedup", f"alternative {i} duplicates schedule bits "
                f"{list(alt.x)} (each schedule must be evaluated once)")
        seen_x.add(alt.x)
        if alt.R is not None and alt.R != sum(alt.x):
            bad("plan/alt", f"alternative {i} claims R={alt.R} but its bits "
                f"sum to {sum(alt.x)}")
        if cap is not None and sum(alt.x) > cap:
            bad("plan/budget", f"alternative {i} ({alt.strategy!r}) spends "
                f"R={sum(alt.x)} > effective cap {cap}")
    return out


# --- trace / serving level ----------------------------------------------------


def _check_phases(out: list[Violation], loc: str, n: int, r: int,
                  phases, expected: Sequence[tuple[str, float, str]] | None
                  ) -> None:
    """Shared phase checks for trace plans, served plans, window choices."""
    if expected is not None and len(phases) != len(expected):
        out.append(Violation(
            rule="trace/phase", location=loc,
            message=f"{len(phases)} planned phases != {len(expected)} "
                    f"flattened trace phases"))
        expected = None
    for i, p in enumerate(phases):
        where = f"{loc} phase {i} ({p.tag or p.kind})"
        if expected is not None:
            kind, m, tag = expected[i]
            if (p.kind, p.tag) != (kind, tag) or p.m_bytes != m:
                out.append(Violation(
                    rule="trace/phase", location=where,
                    message=f"planned ({p.kind!r}, m={p.m_bytes}, "
                            f"{p.tag!r}) != trace event ({kind!r}, m={m}, "
                            f"{tag!r})"))
        _check_schedule_header(out, "trace/phase", where, p.schedule,
                               p.kind, n, r)
        if p.schedule.kind == p.kind and p.schedule.n == n \
                and p.schedule.r == r:
            out.extend(verify_schedule(p.schedule))
            paid = _paid_reconfigs(p.schedule)
            if p.paid_reconfigs != paid:
                out.append(Violation(
                    rule="trace/paid", location=where,
                    message=f"paid_reconfigs {p.paid_reconfigs} != {paid} "
                            f"boundaries whose segment gcds differ"))
        if p.time < 0:
            out.append(Violation(
                rule="trace/phase", location=where,
                message=f"negative phase time {p.time}"))


def verify_trace_plan(tp, cm: "CostModel | None" = None) -> list[Violation]:
    """Every trace-level rule on one `TracePlan`.

    ``cm`` re-derives the boundary-cost and delta-budget ledgers (the plan
    records the budget but not the cost model); without it only the
    cost-model-independent rules run.
    """
    out: list[Violation] = []
    n, r = tp.trace.n, tp.trace.r
    loc = f"trace {tp.trace.name!r} n={n} mode={tp.mode}"

    def bad(rule: str, message: str, repro: str = ""):
        out.append(Violation(rule=rule, location=loc, message=message,
                             repro=repro))

    if tp.mode not in TRACE_MODES:
        bad("trace/phase", f"unknown mode {tp.mode!r}")
    _check_phases(out, loc, n, r, tp.phases, tp.trace.phases())

    # trace/boundary: changed-circuit sets re-derived from raw segment gcds;
    # cold mode re-establishes every boundary with a full-fabric swap
    P = len(tp.phases)
    if len(tp.boundary_changed) != max(0, P - 1) \
            or len(tp.boundary_cost) != max(0, P - 1):
        bad("trace/boundary",
            f"{len(tp.boundary_changed)} boundary entries for {P} phases")
    else:
        for i, (prev, nxt) in enumerate(zip(tp.phases, tp.phases[1:],
                                            strict=False)):
            if tp.mode == "cold":
                want = n
            else:
                want = changed_links(n, _first_last_g(prev.schedule)[1],
                                     _first_last_g(nxt.schedule)[0])
            if tp.boundary_changed[i] != want:
                bad("trace/boundary",
                    f"boundary {i} claims {tp.boundary_changed[i]} changed "
                    f"circuits, re-derived {want}",
                    repro=f"prev g_last={_first_last_g(prev.schedule)[1]} "
                          f"next g_first={_first_last_g(nxt.schedule)[0]}")
            if cm is not None:
                want_cost = cm.delta_sparse(tp.boundary_changed[i],
                                            tp.overlap)
                if not _close(tp.boundary_cost[i], want_cost):
                    bad("trace/boundary",
                        f"boundary {i} cost {tp.boundary_cost[i]!r} != "
                        f"delta_sparse({tp.boundary_changed[i]}) = "
                        f"{want_cost!r}")
            elif tp.boundary_changed[i] == 0 and tp.boundary_cost[i] != 0.0:
                bad("trace/boundary",
                    f"boundary {i} rewires nothing but charges "
                    f"{tp.boundary_cost[i]}")

    # trace/total: the ledger re-summed
    want_total = sum(p.time for p in tp.phases) + sum(tp.boundary_cost)
    if not _close(tp.total_time, want_total):
        bad("trace/total", f"total_time {tp.total_time!r} != re-summed "
            f"phases + boundaries = {want_total!r}")

    # trace/budget: the delta-budget ledger, re-derived independently of the
    # DP's cap arithmetic
    if tp.delta_budget is not None and cm is not None:
        unit = cm.delta_sparse(n, tp.overlap)
        paid = sum(_paid_reconfigs(p.schedule) for p in tp.phases)
        if unit > 0 and paid * unit > tp.delta_budget * (1 + REL_TOL) + unit * 1e-9:
            bad("trace/budget",
                f"{paid} paid reconfigurations spend {paid * unit!r} s > "
                f"delta_budget {tp.delta_budget!r} s")
    if tp.mode == "static":
        for i, p in enumerate(tp.phases):
            if p.schedule.R != 0:
                bad("trace/budget", f"static mode phase {i} reconfigures "
                    f"(R={p.schedule.R})")
    return out


def verify_served_plan(sp, cm: "CostModel", overlap: float = 0.0
                       ) -> list[Violation]:
    """Every serving-level rule on one `ServedPlan` (see docs/invariants.md)."""
    out: list[Violation] = []
    req = sp.request
    n, r = req.n, req.r
    loc = f"serve n={n} window={len(req.events)} init_g={req.init_g}"

    def bad(rule: str, message: str, repro: str = ""):
        out.append(Violation(rule=rule, location=loc, message=message,
                             repro=repro))

    from repro.workloads.online_planner import _flatten  # typed helper only

    _check_phases(out, loc, n, r, sp.phases, _flatten(req.events))
    if not sp.phases:
        return out

    # serve/entry: entry boundary re-derived from the inherited fabric state
    g_first = _first_last_g(sp.phases[0].schedule)[0]
    want_changed = (0 if req.init_g is None
                    else changed_links(n, req.init_g, g_first))
    if sp.entry_changed != want_changed:
        bad("serve/entry", f"entry_changed {sp.entry_changed} != re-derived "
            f"{want_changed} (init_g={req.init_g} -> g_first={g_first})")
    want_cost = cm.delta_sparse(want_changed, overlap)
    if not _close(sp.entry_cost, want_cost):
        bad("serve/entry", f"entry_cost {sp.entry_cost!r} != "
            f"delta_sparse({want_changed}) = {want_cost!r}")

    # serve/boundary + serve/total: intra-window ledger re-derived
    for i, (prev, nxt) in enumerate(zip(sp.phases, sp.phases[1:],
                                        strict=False)):
        want = changed_links(n, _first_last_g(prev.schedule)[1],
                             _first_last_g(nxt.schedule)[0])
        if i >= len(sp.boundary_changed):
            bad("serve/boundary", f"missing boundary entry {i}")
            continue
        if sp.boundary_changed[i] != want:
            bad("serve/boundary", f"boundary {i} claims "
                f"{sp.boundary_changed[i]} changed circuits, re-derived {want}")
        if not _close(sp.boundary_cost[i], cm.delta_sparse(want, overlap)):
            bad("serve/boundary", f"boundary {i} cost {sp.boundary_cost[i]!r} "
                f"!= delta_sparse({want}) = {cm.delta_sparse(want, overlap)!r}")
    want_total = (sp.entry_cost + sum(p.time for p in sp.phases)
                  + sum(sp.boundary_cost))
    if not _close(sp.total_time, want_total):
        bad("serve/total", f"total_time {sp.total_time!r} != entry + phases "
            f"+ boundaries = {want_total!r}")

    # serve/final: the fabric state handed to the job's next request
    want_final = _first_last_g(sp.phases[-1].schedule)[1]
    if sp.final_g != want_final:
        bad("serve/final", f"final_g {sp.final_g} != the last phase's final "
            f"link offset {want_final}")
    return out


def verify_shared_plan(sp) -> list[Violation]:
    """Every multi-tenant rule on one `workloads.tenancy.SharedPlan`.

    The request embedded in the plan carries the cost model, so the whole
    ledger re-derives from the artifact alone:

      tenant/ports     : port partitions are in-range, correctly sized, and
                         pairwise disjoint (and within each tenant's
                         declared port share).
      tenant/route     : a partitioned tenant's schedules are sized to its
                         own sub-fabric — no circuit can reach another
                         tenant's ports.
      tenant/order     : the time-sliced interleaving preserves each
                         tenant's own event order and covers its trace
                         exactly; completions re-derived from prefix sums.
      tenant/budget    : per-tenant (and global) intra-collective
                         reconfiguration stall re-summed against the caps.
      tenant/isolation : measured isolation ratio consistent with the
                         completion ledger and within the structural bound;
                         shared completion never above the serialized
                         baseline (both metrics).
    """
    out: list[Violation] = []
    req = sp.request
    cm, n, overlap = req.cost_model, req.n, req.overlap
    loc = f"shared {str(req.sharing)} K={len(req.tenants)} n={n}"

    def bad(rule: str, message: str, repro: str = ""):
        out.append(Violation(rule=rule, location=loc, message=message,
                             repro=repro))

    specs = {t.name: t for t in req.tenants}
    plans = {t.name: t for t in sp.tenants}
    if set(specs) != set(plans):
        bad("tenant/order", f"tenant plans {sorted(plans)} != requested "
            f"tenants {sorted(specs)}")
        return out
    budgets = req.resolved_budgets()

    if str(req.sharing) == "port-partition":
        taken: list[tuple[int, int, str]] = []
        for t in sp.tenants:
            spec = specs[t.name]
            where = f"{loc} tenant {t.name!r}"
            if t.ports is None or t.plan is None:
                bad("tenant/ports", f"tenant {t.name!r} has no port range "
                    f"or plan under port partitioning")
                continue
            lo, hi = t.ports
            if not (0 <= lo < hi <= n):
                bad("tenant/ports", f"tenant {t.name!r} range [{lo}, {hi}) "
                    f"is outside the fabric's [0, {n})")
            if hi - lo != spec.trace.n:
                bad("tenant/ports", f"tenant {t.name!r} owns {hi - lo} "
                    f"ports but its world is {spec.trace.n}")
            if spec.port_share is not None \
                    and hi - lo > spec.port_share * n + 1e-12:
                bad("tenant/ports", f"tenant {t.name!r} owns {hi - lo} "
                    f"ports > its share {spec.port_share} of n={n}")
            for lo2, hi2, other in taken:
                if lo < hi2 and lo2 < hi:
                    bad("tenant/ports", f"tenant {t.name!r} range "
                        f"[{lo}, {hi}) overlaps {other!r} [{lo2}, {hi2})")
            taken.append((lo, hi, t.name))
            # tenant/route: every schedule must be sized to the tenant's own
            # sub-fabric — a wider schedule would route across the partition
            for i, p in enumerate(t.plan.phases):
                if p.schedule.n != hi - lo:
                    bad("tenant/route",
                        f"tenant {t.name!r} phase {i} schedule spans "
                        f"n={p.schedule.n} != its {hi - lo}-port partition")
            out.extend(verify_trace_plan(t.plan, cm))
            if not _close(t.completion_s, t.plan.total_time):
                bad("tenant/order", f"tenant {t.name!r} completion "
                    f"{t.completion_s!r} != its plan's total "
                    f"{t.plan.total_time!r}  [{where}]")
    else:
        # tenant/order: per-tenant phase subsequences re-matched against
        # the traces, completions re-derived from the prefix-sum ledger
        seen = {name: 0 for name in specs}
        prefix, last_done = 0.0, {name: 0.0 for name in specs}
        g = None
        for i, ph in enumerate(sp.phases):
            if ph.tenant not in specs:
                bad("tenant/order", f"phase {i} owned by unknown tenant "
                    f"{ph.tenant!r}")
                continue
            expected = specs[ph.tenant].trace.phases()
            j = seen[ph.tenant]
            if j >= len(expected):
                bad("tenant/order", f"phase {i} is tenant {ph.tenant!r}'s "
                    f"{j + 1}th phase; its trace has only {len(expected)}")
            else:
                kind, m, tag = expected[j]
                if (ph.plan.kind, ph.plan.m_bytes, ph.plan.tag) \
                        != (kind, m, tag):
                    bad("tenant/order", f"phase {i} planned "
                        f"({ph.plan.kind!r}, m={ph.plan.m_bytes}, "
                        f"{ph.plan.tag!r}) != tenant {ph.tenant!r}'s next "
                        f"event ({kind!r}, m={m}, {tag!r})")
            seen[ph.tenant] = j + 1
            want_changed = (0 if g is None else
                            changed_links(n, g,
                                          _first_last_g(ph.plan.schedule)[0]))
            if ph.boundary_changed != want_changed:
                bad("tenant/order", f"phase {i} hand-off claims "
                    f"{ph.boundary_changed} changed circuits, re-derived "
                    f"{want_changed}")
            want_cost = (cm.delta_sparse(want_changed, overlap)
                         if g is not None else 0.0)
            if not _close(ph.boundary_cost, want_cost):
                bad("tenant/order", f"phase {i} hand-off cost "
                    f"{ph.boundary_cost!r} != delta_sparse"
                    f"({want_changed}) = {want_cost!r}")
            g = _first_last_g(ph.plan.schedule)[1]
            prefix += ph.boundary_cost + ph.plan.time
            last_done[ph.tenant] = prefix
        for name, cnt in seen.items():
            want = len(specs[name].trace.phases())
            if cnt != want:
                bad("tenant/order", f"tenant {name!r} got {cnt} phases, "
                    f"its trace flattens to {want}")
        for t in sp.tenants:
            if not _close(t.completion_s, last_done[t.name]):
                bad("tenant/order", f"tenant {t.name!r} completion "
                    f"{t.completion_s!r} != re-derived prefix sum "
                    f"{last_done[t.name]!r}")
        if not _close(sp.makespan_s, prefix):
            bad("tenant/order", f"makespan {sp.makespan_s!r} != re-summed "
                f"phases + hand-offs = {prefix!r}")

    # tenant/budget: the stall ledgers re-summed against per-tenant caps and
    # the global cap (same arithmetic slack as trace/budget)
    unit = cm.delta_sparse(n, overlap)
    total_paid = 0
    for t in sp.tenants:
        if str(req.sharing) == "port-partition" and t.plan is not None:
            paid = sum(_paid_reconfigs(p.schedule) for p in t.plan.phases)
            t_unit = cm.delta_sparse(specs[t.name].trace.n, overlap)
        else:
            paid = sum(_paid_reconfigs(ph.plan.schedule)
                       for ph in sp.phases if ph.tenant == t.name)
            t_unit = unit
        total_paid += paid
        if t.paid_reconfigs != paid:
            bad("tenant/budget", f"tenant {t.name!r} claims "
                f"{t.paid_reconfigs} paid reconfigurations, re-derived "
                f"{paid}")
        budget = budgets.get(t.name)
        if budget is not None and t_unit > 0 \
                and paid * t_unit > budget * (1 + REL_TOL) + t_unit * 1e-9:
            bad("tenant/budget", f"tenant {t.name!r} spends "
                f"{paid * t_unit!r} s of intra-collective stall > its "
                f"budget {budget!r} s")
    if req.delta_budget is not None and unit > 0 \
            and total_paid * unit > req.delta_budget * (1 + REL_TOL) \
            + unit * 1e-9:
        bad("tenant/budget", f"fleet spends {total_paid * unit!r} s > the "
            f"global budget {req.delta_budget!r} s")

    # tenant/isolation: the measured ratios and the structural bound
    weighted = sum(t.weight * t.completion_s for t in sp.tenants)
    if not _close(sp.weighted_completion_s, weighted):
        bad("tenant/isolation", f"weighted completion "
            f"{sp.weighted_completion_s!r} != re-summed {weighted!r}")
    if sp.makespan_s > sp.serialized_s * (1 + REL_TOL):
        bad("tenant/isolation", f"shared makespan {sp.makespan_s!r} > "
            f"serialized baseline {sp.serialized_s!r}")
    if sp.weighted_completion_s > sp.serialized_weighted_s * (1 + REL_TOL):
        bad("tenant/isolation", f"shared weighted completion "
            f"{sp.weighted_completion_s!r} > serialized "
            f"{sp.serialized_weighted_s!r}")
    for t in sp.tenants:
        if t.alone_s > 0 and not _close(t.isolation,
                                        t.completion_s / t.alone_s):
            bad("tenant/isolation", f"tenant {t.name!r} isolation "
                f"{t.isolation!r} != completion/alone = "
                f"{t.completion_s / t.alone_s!r}")
        if t.isolation > t.isolation_bound * (1 + REL_TOL):
            bad("tenant/isolation", f"tenant {t.name!r} isolation "
                f"{t.isolation!r} exceeds its bound {t.isolation_bound!r}")
    return out


def verify_window_choice(n: int, chosen, *, init_spent: int = 0,
                         cap: int | None = None,
                         label: str = "window") -> list[Violation]:
    """Audit one window DP solution (a `PhaseCandidate` list) before any of
    it is committed — the online planner's warm-started suffix re-plans go
    through this, so a corrupt candidate table can never move the committed
    fabric-state ledger."""
    out: list[Violation] = []
    spent = init_spent
    for i, cand in enumerate(chosen):
        loc = f"{label} phase {i} ({cand.strategy})"
        out.extend(verify_schedule(cand.schedule))
        g_first, g_last = _first_last_g(cand.schedule)
        if (cand.g_first, cand.g_last) != (g_first, g_last):
            out.append(Violation(
                rule="window/g", location=loc,
                message=f"candidate claims (g_first={cand.g_first}, "
                        f"g_last={cand.g_last}), schedule has ({g_first}, "
                        f"{g_last}): carryover boundaries would be mispriced"))
        paid = _paid_reconfigs(cand.schedule)
        if cand.paid != paid:
            out.append(Violation(
                rule="window/paid", location=loc,
                message=f"candidate claims {cand.paid} paid reconfigs, "
                        f"schedule pays {paid}"))
        if cand.time < 0:
            out.append(Violation(
                rule="window/g", location=loc,
                message=f"negative phase time {cand.time}"))
        spent += paid
    if cap is not None and spent > cap:
        out.append(Violation(
            rule="window/cap", location=label,
            message=f"window spends {spent} reconfigurations "
                    f"(init {init_spent}) > trace-wide cap {cap}"))
    return out


# --- fault timelines / degraded state / recovery ------------------------------


def verify_timeline(tl) -> list[Violation]:
    """Structural validity of a `core.faults.FaultTimeline`, re-derived
    independently of its constructor checks (a timeline deserialized or
    field-copied past `__post_init__` must still be rejected here)."""
    from repro.core.faults import DELIVERY_POLICIES, FAULT_KINDS

    out: list[Violation] = []
    loc = f"faults n={tl.n}"

    def bad(rule: str, message: str, repro: str = ""):
        out.append(Violation(rule=rule, location=loc, message=message,
                             repro=repro))

    if tl.n < 2:
        bad("fault/spec", f"need at least 2 nodes, got n={tl.n}")
    if tl.policy not in DELIVERY_POLICIES:
        bad("fault/spec", f"delivery policy {tl.policy!r} is not one of "
            f"{DELIVERY_POLICIES}")
    if not tl.faults:
        bad("fault/spec", "a fault timeline needs at least one fault")
    for i, f in enumerate(tl.faults):
        where = f" fault {i}"
        if f.kind not in FAULT_KINDS:
            bad("fault/spec", f"kind {f.kind!r} is not one of {FAULT_KINDS}",
                repro=where)
        if not (math.isfinite(f.time) and f.time >= 0):
            bad("fault/spec", f"time {f.time} must be finite and >= 0",
                repro=where)
        if not (math.isfinite(f.repair_s) and f.repair_s >= 0):
            bad("fault/spec", f"repair_s {f.repair_s} must be finite and "
                f">= 0", repro=where)
        if f.repair_s > 0 and f.kind != "link-flap":
            bad("fault/spec", f"repair_s {f.repair_s} on a {f.kind!r} fault "
                f"(only link-flap repairs)", repro=where)
        if f.kind == "node-join":
            if f.node != tl.n:
                bad("fault/spec", f"node-join must join at index n={tl.n}, "
                    f"got node={f.node}", repro=where)
        elif not 0 <= f.node < tl.n:
            bad("fault/spec", f"node {f.node} outside [0, {tl.n})",
                repro=where)
    for i, (a, b) in enumerate(zip(tl.faults, tl.faults[1:], strict=False)):
        if b.time < a.time:
            bad("fault/order", f"fault {i + 1} at t={b.time} precedes fault "
                f"{i} at t={a.time}: timelines are time-sorted")
    return out


def verify_degraded(ds, phases=None, chunks_per_msg: int = 32
                    ) -> list[Violation]:
    """Consistency of a `core.faults.DegradedState` against its fault.

    ``phases`` (the (schedule, m) pairs the faulted run played) enables the
    chunk-conservation recount: the committed chunks are re-derived from the
    committed phases' tapes — n * C * sum(segment hops) per phase — instead
    of trusting the engine's counter.
    """
    from repro.core.faults import (ABRUPT_KINDS, DELIVERY_POLICIES,
                                   world_after)

    out: list[Violation] = []
    loc = f"degraded n={ds.n} kind={ds.fault.kind}"

    def bad(rule: str, message: str, repro: str = ""):
        out.append(Violation(rule=rule, location=loc, message=message,
                             repro=repro))

    # fault/mask: surviving world and dead circuits re-derived from the kind
    survivors, dead = world_after(ds.n, ds.fault)
    if tuple(ds.survivors) != survivors:
        bad("fault/mask", f"survivors {tuple(ds.survivors)} != re-derived "
            f"{survivors} for a {ds.fault.kind} at node {ds.fault.node}")
    if tuple(ds.dead_ports) != dead:
        bad("fault/mask", f"dead_ports {tuple(ds.dead_ports)} != re-derived "
            f"{dead}")
    if set(ds.dead_ports) & set(ds.survivors):
        bad("fault/mask", f"dead ports {tuple(ds.dead_ports)} overlap the "
            f"surviving world: traffic would route over a dead circuit")
    if ds.new_n < 2:
        bad("fault/mask", f"surviving world has {ds.new_n} nodes; schedules "
            f"need at least 2")
    abrupt = ds.fault.kind in ABRUPT_KINDS
    if abrupt and ds.aborted_phase != ds.completed_phases:
        bad("fault/mask", f"abrupt {ds.fault.kind} must abort the phase "
            f"after the committed prefix: aborted_phase={ds.aborted_phase} "
            f"!= completed_phases={ds.completed_phases}")
    if not abrupt and ds.aborted_phase is not None:
        bad("fault/mask", f"graceful {ds.fault.kind} drains the in-flight "
            f"phase, but aborted_phase={ds.aborted_phase}")
    if ds.completed_phases < 0:
        bad("fault/mask", f"completed_phases {ds.completed_phases} < 0")
    if phases is not None and ds.completed_phases >= len(phases):
        bad("fault/mask", f"completed_phases {ds.completed_phases} leaves no "
            f"work in a {len(phases)}-phase trace: the fault never took "
            f"effect")
    if ds.completed_phases > 0 and ds.snapshot is None:
        bad("fault/mask", f"{ds.completed_phases} committed phases but no "
            f"committed-prefix snapshot")

    # resume-clock re-derivation per kind
    if ds.fault.kind == "link-down" and ds.resume_clock != ds.fault.time:
        bad("fault/mask", f"link-down resumes at the fault time "
            f"{ds.fault.time!r}, got {ds.resume_clock!r}")
    if ds.fault.kind == "link-flap" \
            and ds.resume_clock != ds.fault.time + ds.fault.repair_s:
        bad("fault/mask", f"link-flap resumes at fault time + repair = "
            f"{ds.fault.time + ds.fault.repair_s!r}, got {ds.resume_clock!r}")
    if ds.snapshot is not None:
        if not abrupt and not _close(ds.resume_clock, ds.snapshot.clock):
            bad("fault/mask", f"graceful faults resume at the drained "
                f"boundary's clock {ds.snapshot.clock!r}, got "
                f"{ds.resume_clock!r}")
        if ds.snapshot.clock > ds.resume_clock * (1 + REL_TOL) + REL_TOL:
            bad("fault/mask", f"snapshot clock {ds.snapshot.clock!r} is past "
                f"the resume clock {ds.resume_clock!r}: the committed prefix "
                f"would not have drained before the fault")
        out.extend(verify_snapshot(ds.snapshot))

    # fault/conserve: the chunk ledger
    if ds.policy not in DELIVERY_POLICIES:
        bad("fault/conserve", f"delivery policy {ds.policy!r} is not one of "
            f"{DELIVERY_POLICIES}")
    for name in ("committed_chunks", "in_flight_chunks", "lost_chunks",
                 "requeued_chunks"):
        if getattr(ds, name) < 0:
            bad("fault/conserve", f"{name} {getattr(ds, name)} < 0")
    if ds.lost_chunks + ds.requeued_chunks != ds.in_flight_chunks:
        bad("fault/conserve",
            f"lost {ds.lost_chunks} + requeued {ds.requeued_chunks} != "
            f"in-flight {ds.in_flight_chunks}: chunks leaked at the fault")
    if ds.policy == "drop" and ds.requeued_chunks:
        bad("fault/conserve", f"policy 'drop' re-queued "
            f"{ds.requeued_chunks} chunks")
    if ds.policy == "requeue" and ds.lost_chunks:
        bad("fault/conserve", f"policy 'requeue' lost {ds.lost_chunks} "
            f"chunks")
    if not abrupt and ds.in_flight_chunks:
        bad("fault/conserve", f"graceful {ds.fault.kind} drains the "
            f"in-flight phase, but {ds.in_flight_chunks} chunks were in "
            f"flight")
    if phases is not None and ds.completed_phases <= len(phases):
        C = max(1, int(chunks_per_msg))
        want = sum(ds.n * C * sum(compile_tape(s).seg_hops)
                   for s, _ in phases[:ds.completed_phases])
        if ds.committed_chunks != want:
            bad("fault/conserve",
                f"committed_chunks {ds.committed_chunks} != {want} services "
                f"recounted from the {ds.completed_phases} committed phases' "
                f"tapes (n * C * segment hops)")
    return out


def verify_recovery(ds, recovery_plan, clean_plan=None) -> list[Violation]:
    """Audit a degraded-mode recovery plan against its `DegradedState`.

    ``fault/route``: the plan must target exactly the surviving world — no
    schedule may route traffic over a dead circuit or a departed node.
    ``fault/replan``: against ``clean_plan`` (the offline carryover plan of
    the reduced trace), the recovery plan must be bit-identical — same
    schedules, same total — so the recovered result matches a clean run of
    the reduced world exactly.
    """
    out: list[Violation] = []
    loc = f"recovery n={ds.n}->{ds.new_n} kind={ds.fault.kind}"

    def bad(rule: str, message: str, repro: str = ""):
        out.append(Violation(rule=rule, location=loc, message=message,
                             repro=repro))

    if set(ds.dead_ports) & set(ds.survivors):
        bad("fault/route", f"dead ports {tuple(ds.dead_ports)} overlap the "
            f"surviving world {tuple(ds.survivors)}")
    if recovery_plan.trace.n != ds.new_n:
        bad("fault/route",
            f"recovery plan targets n={recovery_plan.trace.n}, the "
            f"surviving world has {ds.new_n} nodes: traffic would be routed "
            f"over the {'dead circuit' if ds.dead_ports else 'old world'}")
    for i, p in enumerate(recovery_plan.phases):
        if p.schedule.n != ds.new_n:
            bad("fault/route", f"phase {i} schedule is for n={p.schedule.n} "
                f"!= surviving {ds.new_n}")
    out.extend(verify_trace_plan(recovery_plan))

    if clean_plan is not None:
        if clean_plan.trace.n != ds.new_n:
            bad("fault/replan", f"clean reference plan targets "
                f"n={clean_plan.trace.n} != surviving {ds.new_n}")
        if recovery_plan.schedules() != clean_plan.schedules():
            bad("fault/replan",
                "recovery schedules differ from the offline carryover plan "
                "of the reduced trace: the recovered result cannot be "
                "bit-identical to a clean run at the reduced n")
        elif recovery_plan.total_time != clean_plan.total_time:
            bad("fault/replan",
                f"identical schedules but total {recovery_plan.total_time!r}"
                f" != clean {clean_plan.total_time!r}: the boundary ledger "
                f"diverged")
    return out


# --- fabric snapshots ---------------------------------------------------------


def verify_snapshot(snap: FabricSnapshot) -> list[Violation]:
    """Structural validity of a resumable fabric state."""
    out: list[Violation] = []
    loc = f"snapshot n={snap.n}"

    def bad(rule: str, message: str):
        out.append(Violation(rule=rule, location=loc, message=message))

    if snap.n < 2:
        bad("snap/shape", f"need at least 2 nodes, got n={snap.n}")
        return out
    for name in ("node_ready", "port_free"):
        v = getattr(snap, name)
        if len(v) != snap.n:
            bad("snap/shape", f"{name} has length {len(v)} != n={snap.n}")
        elif any(not (t >= 0.0 and math.isfinite(t)) for t in v):
            bad("snap/range", f"{name} entries must be finite and >= 0")
    if not 1 <= snap.link_offset < snap.n:
        bad("snap/range", f"link_offset {snap.link_offset} outside [1, n): "
            f"not a subring the fabric can be parked on")
    if snap.chunks_moved < 0 or snap.reconfigs_paid < 0 \
            or snap.delta_stall < 0:
        bad("snap/range", "prefix accounting must be >= 0, got "
            f"(chunks={snap.chunks_moved}, paid={snap.reconfigs_paid}, "
            f"stall={snap.delta_stall})")
    return out


def clear_verifier_caches() -> None:
    """Drop memoized per-schedule/tape verification results."""
    verify_tape.cache_clear()
    verify_schedule.cache_clear()
