"""Structured findings of the static verifier.

Every rule the verifier checks has a stable dotted id (``tape/gcd``,
``plan/entry``, ...) catalogued in ``docs/invariants.md`` together with the
paper condition it encodes.  A finding is a `Violation` record: the rule id,
where in the artifact it fired, a human-readable message, and a small repro
snippet (enough context to reconstruct the failing check by hand).  Callers
that want hard failure semantics use `raise_on_violations`, which wraps the
findings in a `VerificationError`.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One static-verification finding.

    rule     : stable rule id, e.g. 'tape/gcd' (see docs/invariants.md).
    location : where the rule fired, e.g. 'a2a n=16 step 3'.
    message  : what was expected vs what the artifact claims.
    severity : 'error' (invariant broken) or 'warning' (suspicious).
    repro    : small snippet of the offending values, for bug reports.
    """

    rule: str
    location: str
    message: str
    severity: str = "error"
    repro: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}")

    def __str__(self) -> str:
        tail = f" [{self.repro}]" if self.repro else ""
        return f"[{self.rule}] {self.location}: {self.message}{tail}"


class VerificationError(ValueError):
    """Raised when an artifact fails static verification at a trust boundary.

    Carries the full list of findings; str() renders them one per line so a
    planner/serving failure log shows every broken invariant, not just the
    first.
    """

    def __init__(self, violations: Sequence[Violation], context: str = ""):
        self.violations = tuple(violations)
        self.context = context
        head = (f"{context}: " if context else "") + (
            f"{len(self.violations)} static verification failure(s)")
        lines = [head] + [f"  - {v}" for v in self.violations]
        super().__init__("\n".join(lines))


def raise_on_violations(violations: Sequence[Violation],
                        context: str = "") -> None:
    """Raise `VerificationError` iff any error-severity finding is present."""
    errors = [v for v in violations if v.severity == "error"]
    if errors:
        raise VerificationError(errors, context)
