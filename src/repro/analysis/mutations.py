"""Mutation-testing harness for the static verifier.

Each `Mutation` corrupts a known-good artifact — a compiled tape, a
`PlanResult` fresh out of the planner, a `TracePlan`, a `ServedPlan`, a
window DP solution, a `FabricSnapshot` — in one specific way and names the
rule id that must catch it.  `run_mutations()` executes them all; the tier-1
test (tests/test_verifier.py) asserts every corruption is caught by its
designated rule, so a verifier rule that silently stops firing fails the
build.

Corruptions bypass the constructors' own validation on purpose
(``dataclasses.replace`` on tapes, field-copied `Schedule` /
`FabricSnapshot` objects): the verifier's job is exactly the artifacts that
*look* well-formed — deserialized from a cache, produced by a buggy DP, or
handed over by another tenant — and the harness must reach the states
post-init checks would reject.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

from repro.core.batchsim import FabricSnapshot, compile_tape
from repro.core.cost_model import PAPER_DEFAULT
from repro.core.schedules import Schedule, every_step_schedule, static_schedule

from .verifier import (verify_degraded, verify_plan, verify_recovery,
                       verify_schedule, verify_served_plan,
                       verify_shared_plan, verify_snapshot, verify_tape,
                       verify_timeline, verify_trace_plan,
                       verify_window_choice)
from .violations import Violation

MB = 1024.0 ** 2


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One named corruption and the rule id that must catch it."""

    name: str
    rule: str
    build: Callable[[], Sequence[Violation]]


@dataclasses.dataclass(frozen=True)
class MutationOutcome:
    name: str
    rule: str
    caught: bool
    fired: tuple[str, ...]


def _tweak(t: tuple, i: int, v) -> tuple:
    return t[:i] + (v,) + t[i + 1:]


def _field_copy(obj, **overrides):
    """Clone a frozen dataclass without running __post_init__ (reaches the
    states the constructors themselves would reject)."""
    clone = object.__new__(type(obj))
    for f in dataclasses.fields(obj):
        object.__setattr__(clone, f.name,
                           overrides.get(f.name, getattr(obj, f.name)))
    return clone


# --- known-good fixtures (built lazily, shared across mutations) --------------


@functools.lru_cache(maxsize=None)
def _good_schedule() -> Schedule:
    # two segments with distinct gcds (1 and 4): one paid boundary
    return Schedule(kind="a2a", n=16, x=(0, 0, 1, 0), r=2)


def _good_tape():
    return compile_tape(_good_schedule())


@functools.lru_cache(maxsize=None)
def _planner():
    from repro.planner import Planner  # deferred: planner imports analysis

    return Planner(cache_size=32)


@functools.lru_cache(maxsize=None)
def _good_plan():
    from repro.planner import PlanRequest

    return _planner().plan(PlanRequest(kind="a2a", n=16, m_bytes=MB,
                                       init_g=2))


@functools.lru_cache(maxsize=None)
def _good_capped_plan():
    from repro.planner import PlanRequest

    return _planner().plan(PlanRequest(kind="a2a", n=16, m_bytes=MB,
                                       max_R=1))


@functools.lru_cache(maxsize=None)
def _good_trace_plan():
    from repro.workloads.trace_planner import plan_trace
    from repro.workloads.traces import CollectiveEvent, Trace

    trace = Trace(name="mutation-fixture", n=16, events=(
        CollectiveEvent(kind="a2a", m_bytes=MB, tag="t0"),
        CollectiveEvent(kind="ag", m_bytes=MB / 2, tag="t1")))
    return plan_trace(trace, PAPER_DEFAULT, mode="carryover",
                      planner=_planner())


@functools.lru_cache(maxsize=None)
def _good_served_plan():
    from repro.workloads.serve import PlanService, ServeRequest
    from repro.workloads.traces import CollectiveEvent

    service = PlanService(cm=PAPER_DEFAULT, cache_size=0, planner=_planner())
    return service.serve(ServeRequest(events=(
        CollectiveEvent(kind="a2a", m_bytes=MB, tag="t0"),
        CollectiveEvent(kind="ag", m_bytes=MB / 2, tag="t1")),
        n=16, init_g=2))


@functools.lru_cache(maxsize=None)
def _good_window_choice():
    from repro.workloads.trace_planner import phase_candidates, window_dp

    cands = phase_candidates("a2a", 16, 2, MB, PAPER_DEFAULT, "ocs", 0.0,
                             _planner())
    return tuple(window_dp(16, [cands, cands], PAPER_DEFAULT, init_g=1))


@functools.lru_cache(maxsize=None)
def _good_shared_plan():
    """One real time-sliced shared plan (K=2 tenants on one 16-port fabric)."""
    from repro.workloads.tenancy import (SharedFabricRequest, TenantSpec,
                                         plan_shared)
    from repro.workloads.traces import CollectiveEvent, Trace

    ta = Trace(name="mut-a", n=16, events=(
        CollectiveEvent(kind="a2a", m_bytes=MB, tag="t0"),
        CollectiveEvent(kind="ag", m_bytes=MB / 2, tag="t1")))
    tb = Trace(name="mut-b", n=16, events=(
        CollectiveEvent(kind="ag", m_bytes=MB / 4, tag="t0"),))
    return plan_shared(SharedFabricRequest(
        tenants=(TenantSpec("a", ta, weight=2.0), TenantSpec("b", tb)),
        n=16, cost_model=PAPER_DEFAULT), planner=_planner())


@functools.lru_cache(maxsize=None)
def _good_partition_plan():
    """One real port-partitioned shared plan (8 + 4 ports of 16)."""
    from repro.workloads.tenancy import (SharedFabricRequest, TenantSpec,
                                         plan_shared)
    from repro.core.jsonio import SharingMode
    from repro.workloads.traces import CollectiveEvent, Trace

    tc = Trace(name="mut-c", n=8, events=(
        CollectiveEvent(kind="a2a", m_bytes=MB, tag="t0"),))
    td = Trace(name="mut-d", n=4, events=(
        CollectiveEvent(kind="ag", m_bytes=MB / 2, tag="t0"),))
    return plan_shared(SharedFabricRequest(
        tenants=(TenantSpec("c", tc), TenantSpec("d", td)),
        n=16, cost_model=PAPER_DEFAULT,
        sharing=SharingMode.PORT_PARTITION), planner=_planner())


@functools.lru_cache(maxsize=None)
def _good_snapshot() -> FabricSnapshot:
    return FabricSnapshot(n=8, link_offset=2, node_ready=(1.0,) * 8,
                          port_free=(1.5,) * 8)


@functools.lru_cache(maxsize=None)
def _good_recovery():
    """One real fault-recovery cycle (link-down halfway through a small
    mixed trace at n=8) — source of the DegradedState / recovery-plan
    fixtures the fault/* mutations corrupt."""
    from repro.core.fabricsim import FabricSim
    from repro.core.faults import FaultSpec, FaultTimeline
    from repro.workloads.recovery import run_with_recovery
    from repro.workloads.trace_planner import plan_trace
    from repro.workloads.traces import mixed_trace

    trace = mixed_trace(8, moe_layers=1, train_steps=1, decode_steps=2)
    plan = plan_trace(trace, PAPER_DEFAULT, mode="carryover",
                      planner=_planner())
    clean = FabricSim(mode="sparse", chunks_per_msg=8).run_trace(
        plan.fabric_phases(), PAPER_DEFAULT)
    tl = FaultTimeline(n=8, faults=(
        FaultSpec(kind="link-down", time=0.5 * clean.completion, node=3),))
    return run_with_recovery(trace, PAPER_DEFAULT, faults=tl,
                             planner=_planner(), verify=False)


@functools.lru_cache(maxsize=None)
def _good_timeline():
    from repro.core.faults import FaultTimeline

    ds = _good_recovery().degraded
    return FaultTimeline(n=ds.n, faults=(ds.fault,))


# --- the corruption catalogue -------------------------------------------------


def _mut_tape(rule: str, **overrides):
    def build():
        return verify_tape(dataclasses.replace(_good_tape(), **overrides))
    return build


def _mut_plan(fixture, **overrides):
    def build():
        return verify_plan(dataclasses.replace(fixture(), **overrides))
    return build


def _mut_trace(**overrides):
    def build():
        return verify_trace_plan(
            dataclasses.replace(_good_trace_plan(), **overrides),
            cm=PAPER_DEFAULT)
    return build


def _mut_serve(**overrides):
    def build():
        return verify_served_plan(
            dataclasses.replace(_good_served_plan(), **overrides),
            PAPER_DEFAULT)
    return build


def _build_mutations() -> tuple[Mutation, ...]:
    t = _good_tape()

    def bad_x_schedule():
        return verify_schedule(_field_copy(_good_schedule(), x=(1, 0, 1, 0)))

    def plan_kind():
        res = _good_plan()
        wrong = static_schedule("rs", 16)
        return verify_plan(dataclasses.replace(res, schedule=wrong))

    def plan_budget():
        res = _good_capped_plan()
        over = every_step_schedule("a2a", 16)  # R=3 > max_R=1
        return verify_plan(dataclasses.replace(res, schedule=over))

    def plan_rank():
        res = _good_plan()
        return verify_plan(dataclasses.replace(
            res, alternatives=tuple(reversed(res.alternatives))))

    def plan_dedup():
        res = _good_plan()
        dup = next(a for a in res.alternatives if a.x is not None)
        return verify_plan(dataclasses.replace(
            res, alternatives=res.alternatives + (dup,)))

    def trace_phase():
        tp = _good_trace_plan()
        bad0 = dataclasses.replace(tp.phases[0], kind="ag")
        return verify_trace_plan(
            dataclasses.replace(tp, phases=(bad0,) + tp.phases[1:]),
            cm=PAPER_DEFAULT)

    def trace_paid():
        tp = _good_trace_plan()
        bad0 = dataclasses.replace(tp.phases[0],
                                   paid_reconfigs=tp.phases[0].paid_reconfigs + 1)
        return verify_trace_plan(
            dataclasses.replace(tp, phases=(bad0,) + tp.phases[1:]),
            cm=PAPER_DEFAULT)

    def trace_boundary():
        tp = _good_trace_plan()
        flipped = 0 if tp.boundary_changed[0] else tp.trace.n
        return verify_trace_plan(
            dataclasses.replace(
                tp, boundary_changed=_tweak(tp.boundary_changed, 0, flipped)),
            cm=PAPER_DEFAULT)

    def window_g():
        chosen = _good_window_choice()
        bad0 = dataclasses.replace(chosen[0], g_last=chosen[0].g_last + 1)
        return verify_window_choice(16, (bad0,) + chosen[1:])

    def window_paid():
        chosen = _good_window_choice()
        bad0 = dataclasses.replace(chosen[0], paid=chosen[0].paid + 1)
        return verify_window_choice(16, (bad0,) + chosen[1:])

    def window_cap():
        from repro.workloads.trace_planner import PhaseCandidate

        sched = every_step_schedule("a2a", 16)  # honestly pays 3 reconfigs
        cand = PhaseCandidate(strategy="every-step", schedule=sched,
                              time=1e-3, paid=3, g_first=1, g_last=8)
        # a DP claiming this fits under cap=2 has overspent the trace budget
        return verify_window_choice(16, [cand], cap=2)

    def fault_kind():
        tl = _good_timeline()
        meteor = _field_copy(tl.faults[0], kind="meteor-strike")
        return verify_timeline(_field_copy(tl, faults=(meteor,)))

    def fault_order():
        from repro.core.faults import FaultSpec

        tl = _good_timeline()
        f = tl.faults[0]
        earlier = FaultSpec(kind="node-leave", time=f.time / 2, node=1)
        return verify_timeline(_field_copy(tl, faults=(f, earlier)))

    def fault_mask():
        ds = _good_recovery().degraded
        return verify_degraded(_field_copy(ds,
                                           survivors=tuple(range(ds.n))))

    def fault_leak():
        ds = _good_recovery().degraded
        return verify_degraded(_field_copy(ds,
                                           lost_chunks=ds.lost_chunks + 1))

    def fault_conserve():
        rr = _good_recovery()
        ds = _field_copy(rr.degraded,
                         committed_chunks=rr.degraded.committed_chunks + 1)
        return verify_degraded(ds, phases=rr.plan.fabric_phases(),
                               chunks_per_msg=8)

    def fault_route():
        rr = _good_recovery()
        # the original full-trace plan still targets the pre-fault world:
        # serving it post-fault routes traffic over the dead circuit
        return verify_recovery(rr.degraded, rr.plan)

    def fault_replan():
        rr = _good_recovery()
        # right world size, wrong schedules: the restart plan re-runs the
        # whole trace, not the committed remainder
        return verify_recovery(rr.degraded, rr.restart_plan,
                               clean_plan=rr.clean_plan)

    def tenant_ports():
        sp = _good_partition_plan()
        t0 = _field_copy(sp.tenants[0], ports=(2, 10))
        return verify_shared_plan(_field_copy(sp, tenants=(t0,
                                                           sp.tenants[1])))

    def tenant_route():
        sp = _good_partition_plan()
        # hand tenant 'c' (8 ports) tenant 'd''s 4-node plan: its schedules
        # cannot span the partition it owns
        t0 = _field_copy(sp.tenants[0], plan=sp.tenants[1].plan)
        return verify_shared_plan(_field_copy(sp, tenants=(t0,
                                                           sp.tenants[1])))

    def tenant_order():
        sp = _good_shared_plan()
        return verify_shared_plan(_field_copy(sp, phases=sp.phases[:-1],
                                              order=sp.order[:-1]))

    def tenant_budget():
        sp = _good_shared_plan()
        victim = next(t for t in sp.tenants if t.paid_reconfigs > 0)
        bad = tuple(_field_copy(t, paid_reconfigs=t.paid_reconfigs - 1)
                    if t.name == victim.name else t for t in sp.tenants)
        return verify_shared_plan(_field_copy(sp, tenants=bad))

    def tenant_isolation():
        sp = _good_shared_plan()
        return verify_shared_plan(
            _field_copy(sp, serialized_s=sp.makespan_s / 2))

    def snap_shape():
        return verify_snapshot(_field_copy(
            _good_snapshot(), node_ready=_good_snapshot().node_ready[:-1]))

    def snap_range():
        return verify_snapshot(_field_copy(_good_snapshot(), link_offset=0))

    return (
        # --- tape-level: the link-offset algebra -----------------------------
        Mutation("tape offset not j*r^k", "tape/offset-form",
                 _mut_tape("tape/offset-form",
                           offsets=_tweak(t.offsets, 1, 3))),
        Mutation("tape step order scrambled", "tape/structure",
                 _mut_tape("tape/structure",
                           offsets=tuple(reversed(t.offsets)))),
        Mutation("tape digit-class count off by one", "tape/counts",
                 _mut_tape("tape/counts", counts=_tweak(t.counts, 0,
                                                        t.counts[0] + 1))),
        Mutation("tape duplicated offset breaks conservation",
                 "tape/conserve",
                 _mut_tape("tape/conserve", offsets=_tweak(t.offsets, 1, 1))),
        Mutation("tape link offset not the segment gcd", "tape/gcd",
                 _mut_tape("tape/gcd", g_step=(1, 1, 2, 4),
                           hops=(1, 2, 2, 2))),
        Mutation("tape offset unreachable in subring", "tape/reach",
                 _mut_tape("tape/reach", g_step=(1, 1, 3, 3))),
        Mutation("tape hop count wrong", "tape/hops",
                 _mut_tape("tape/hops", hops=_tweak(t.hops, 3, 5))),
        Mutation("tape segment map shifted", "tape/seg",
                 _mut_tape("tape/seg", seg_of=(0, 1, 1, 1))),
        Mutation("tape changed-circuit set zeroed", "tape/changed",
                 _mut_tape("tape/changed",
                           changed_links=(0,) * len(t.changed_links))),
        Mutation("tape subring offset out of range", "tape/subring",
                 _mut_tape("tape/subring", g_step=(16, 16, 16, 16),
                           seg_g=(16, 16))),
        Mutation("schedule reconfigures before step 0", "sch/x-format",
                 bad_x_schedule),
        # --- plan-level: the planner's trust boundary ------------------------
        Mutation("plan winner schedule of the wrong kind", "plan/kind",
                 plan_kind),
        Mutation("plan winner exceeds reconfiguration cap", "plan/budget",
                 plan_budget),
        Mutation("plan predicted time drifts from breakdown", "plan/entry",
                 _mut_plan(_good_plan,
                           predicted_time=_good_plan().predicted_time + 1e-3)),
        Mutation("plan alternatives unsorted", "plan/rank", plan_rank),
        Mutation("plan alternatives duplicated", "plan/dedup", plan_dedup),
        # --- trace-level: offline DP ledgers ---------------------------------
        Mutation("trace phase kind mismatch", "trace/phase", trace_phase),
        Mutation("trace paid-reconfig ledger off by one", "trace/paid",
                 trace_paid),
        Mutation("trace boundary changed-circuit count flipped",
                 "trace/boundary", trace_boundary),
        Mutation("trace total drifts from ledger", "trace/total",
                 _mut_trace(total_time=_good_trace_plan().total_time + 1e-3)),
        # --- serving-level: PlanService / online window ----------------------
        Mutation("served entry boundary mispriced", "serve/entry",
                 _mut_serve(entry_changed=_good_served_plan().entry_changed - 1)),
        Mutation("served final fabric state wrong", "serve/final",
                 _mut_serve(final_g=_good_served_plan().final_g + 1)),
        Mutation("window candidate misreports final offset", "window/g",
                 window_g),
        Mutation("window candidate misreports paid reconfigs", "window/paid",
                 window_paid),
        Mutation("window DP overspends the trace-wide cap", "window/cap",
                 window_cap),
        # --- multi-tenant shared plans ----------------------------------------
        Mutation("shared partition port ranges overlap", "tenant/ports",
                 tenant_ports),
        Mutation("shared partition schedule spans foreign ports",
                 "tenant/route", tenant_route),
        Mutation("shared interleaving drops a tenant phase", "tenant/order",
                 tenant_order),
        Mutation("shared paid-reconfig ledger understated", "tenant/budget",
                 tenant_budget),
        Mutation("shared makespan above serialized baseline",
                 "tenant/isolation", tenant_isolation),
        # --- fabric snapshots -------------------------------------------------
        Mutation("snapshot port arrays truncated", "snap/shape", snap_shape),
        Mutation("snapshot parked on invalid circuit", "snap/range",
                 snap_range),
        # --- faults / degraded mode / recovery --------------------------------
        Mutation("fault timeline with unknown kind", "fault/spec",
                 fault_kind),
        Mutation("fault timeline out of time order", "fault/order",
                 fault_order),
        Mutation("degraded survivors include the dead port", "fault/mask",
                 fault_mask),
        Mutation("degraded chunk ledger leaks in flight", "fault/conserve",
                 fault_leak),
        Mutation("degraded committed count drifts from tapes",
                 "fault/conserve", fault_conserve),
        Mutation("recovery plan routed over the dead circuit", "fault/route",
                 fault_route),
        Mutation("recovery schedules diverge from clean reference",
                 "fault/replan", fault_replan),
    )


def mutations() -> tuple[Mutation, ...]:
    """The full corruption catalogue (fixtures are built lazily on run)."""
    return _build_mutations()


def run_mutations() -> list[MutationOutcome]:
    """Run every mutation; ``caught`` means the designated rule fired."""
    out = []
    for mut in mutations():
        fired = tuple(sorted({v.rule for v in mut.build()}))
        out.append(MutationOutcome(name=mut.name, rule=mut.rule,
                                   caught=mut.rule in fired, fired=fired))
    return out
