"""Static fast-path certification for the batch fabric engine.

`batchsim._play` guarantees exactness by serving every port's traffic in the
*canonical* order and then checking, from the computed timeline, two runtime
sufficient conditions for the event-driven heap to coincide with it:

  guard 1 (hop-1 / injection order): no relayed hop-1 chunk may arrive at a
      port at or before the port's own step injection;
  guard 2 (cross-step overtaking): within a segment, no step's first arrival
      may precede (or tie with) any earlier step's arrival at the same port.

Those checks cost the engine its ``first_arr`` / ``last_arr`` /
``seg_max_arr`` bookkeeping on every step of every lane.  This module decides
the same question *statically* — from the tape and the cost-model regime
alone, before anything is played — so certified lanes skip the runtime
guards (and therefore the scalar-oracle fallback test) entirely.

Soundness.  Call a lane *uniform* when it has no per-node skew:
``link_speed is None``, ``payload_scale is None``, and (for trace lanes) no
initial snapshot.  On a uniform lane every port sees bit-identical float
values at every stage of the playback — the fabric is rotationally
symmetric, all ports share one ``inj`` / ``F`` / ``tau`` value per step, and
the gather by a constant link offset permutes equal values.  Under that
symmetry:

  - guard 1 is unreachable or strictly satisfied whenever each step has
    ``hops <= 1`` (no relayed stream exists) or its hop-1 arrival is
    strictly later than the injection:
    ``nxt0 = max(F, inj) + tau + alpha_h >= inj + tau + alpha_h > inj``
    as soon as ``tau > 0`` (positive payload: ``m * beta > 0``) or
    ``alpha_h > 0``.
  - guard 2 is strictly satisfied whenever ``alpha_s > 0``: step k's first
    arrival is its injection ``recv_{k-1} + alpha_s`` (relayed arrivals only
    add non-negative ``tau``/``alpha_h`` on top), every earlier arrival
    tracked by ``seg_max_arr`` is bounded by that step's delivery time, and
    deliveries are non-decreasing in canonical order — so the +alpha_s gap
    keeps the comparison strict.

Hence the certificate:

    uniform  AND  alpha_s > 0  AND
    (alpha_h > 0  OR  m * beta > 0  OR  max(hops) <= 1)   per payload phase

It is deliberately *sufficient, not necessary*: skewed lanes and
zero-latency regimes simply fall back to the runtime guards, which remain in
place for uncertified lanes.  The differential grid in
``tests/test_certifier.py`` pins certified lanes bit-exact against the
scalar oracle across the batchsim fuzz grid, and asserts no lane the runtime
guards would have failed is ever certified.

The certificate also gates the JAX backend (`repro.core.batchsim_jax`): the
XLA kernel carries neither the runtime guards nor the per-port skew arrays,
so *only* certified lanes may run on it — certification implies uniformity
(no ``link_speed`` / ``payload_scale``) and proves the guards could not have
tripped, which is exactly what the guard-free kernel needs.
`partition_backends` is the routing decision `batch_run(backend="jax")`
executes: certified lanes to XLA, everything else to the guarded NumPy
playback with its scalar-oracle fallback.

The per-(schedule, regime) decision is memoized, so serving paths that
score the same candidate schedules under one cost model pay the tape scan
once.
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

from repro.core.batchsim import BatchLane, TraceLane, compile_tape
from repro.core.cost_model import CostModel
from repro.core.schedules import Schedule


@functools.lru_cache(maxsize=8192)
def _certify_schedule(schedule: Schedule, alpha_s_pos: bool,
                      alpha_h_pos: bool, payload_pos: bool) -> bool:
    """Memoized per-(schedule, regime) certificate for one uniform payload
    phase.  The regime is collapsed to the three booleans the soundness
    argument actually depends on, so e.g. every positive-payload request
    under one cost model shares a single cache entry per schedule."""
    if not alpha_s_pos:                       # guard 2 needs the +alpha_s gap
        return False
    if alpha_h_pos or payload_pos:            # guard 1 strictly satisfied
        return True
    tape = compile_tape(schedule)
    return max(tape.hops, default=0) <= 1     # guard 1 unreachable


def certify_lane(lane: BatchLane, cm: CostModel) -> bool:
    """True iff ``lane`` provably cannot trip either runtime guard of
    `batchsim._play` (see module docstring), so its vectorized playback is
    exact without the guards or the scalar-oracle fallback."""
    if lane.link_speed is not None or lane.payload_scale is not None:
        return False                          # skew breaks port symmetry
    return _certify_schedule(
        lane.schedule, cm.alpha_s > 0.0, cm.alpha_h > 0.0,
        lane.m_bytes * cm.beta > 0.0)


def certify_trace_lane(lane: TraceLane, cm: CostModel) -> bool:
    """Trace-lane certificate: uniform, not resumed from a snapshot (the
    restored per-port state breaks rotational symmetry), and every payload
    phase individually certified."""
    if lane.link_speed is not None or lane.payload_scale is not None \
            or lane.initial is not None:
        return False
    a_s, a_h = cm.alpha_s > 0.0, cm.alpha_h > 0.0
    return all(
        _certify_schedule(sched, a_s, a_h, m * cm.beta > 0.0)
        for sched, m in lane.phases)


def certify_batch(lanes: Sequence[BatchLane], cm: CostModel) -> np.ndarray:
    """Per-lane certificates as a [B] bool array (batch_run's mask)."""
    return np.array([certify_lane(lane, cm) for lane in lanes], dtype=bool)


def certify_trace_batch(lanes: Sequence[TraceLane],
                        cm: CostModel) -> np.ndarray:
    """Per-lane certificates as a [B] bool array (batch_run_trace's mask)."""
    return np.array([certify_trace_lane(lane, cm) for lane in lanes],
                    dtype=bool)


def partition_backends(lanes: Sequence[BatchLane],
                       cm: CostModel) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split a batch into its JAX-eligible and NumPy-only lanes.

    Returns ``(jax_idx, numpy_idx, certified)``: the certificate mask plus
    the index arrays `batch_run(backend="jax")` routes with.  Eligibility
    *is* certification — there is no separate JAX criterion, because the
    certificate is precisely the proof that the guard-free, skew-free XLA
    kernel computes the same timeline as the guarded NumPy playback.
    """
    certified = certify_batch(lanes, cm)
    return np.flatnonzero(certified), np.flatnonzero(~certified), certified


def clear_certifier_cache() -> None:
    """Drop memoized certificates (benchmarks use this for cold timings)."""
    _certify_schedule.cache_clear()
