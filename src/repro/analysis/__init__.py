"""Static verification of schedules, tapes, plans, and fabric snapshots.

The analysis layer sits at the trust boundaries of the planning/serving
stack (see docs/architecture.md and docs/invariants.md):

  - `verifier`  : rule catalogue re-deriving every claimed invariant from
                  the link-offset algebra, no simulator involved;
  - `certifier` : static fast-path certificates replacing batchsim's
                  runtime canonical-order guards for provably-safe lanes;
  - `mutations` : the corruption harness proving each rule actually fires;
  - `violations`: the structured finding records and raise helpers.

Only `repro.core` is imported at module level, so the planner and workloads
layers can depend on this package without cycles.
"""
from .certifier import (certify_batch, certify_lane, certify_trace_batch,
                        certify_trace_lane, clear_certifier_cache,
                        partition_backends)
from .verifier import (clear_verifier_caches, verify_degraded, verify_plan,
                       verify_recovery, verify_schedule, verify_served_plan,
                       verify_shared_plan, verify_snapshot, verify_tape,
                       verify_timeline, verify_trace_plan,
                       verify_window_choice)
from .violations import VerificationError, Violation, raise_on_violations

__all__ = [
    "Violation", "VerificationError", "raise_on_violations",
    "verify_schedule", "verify_tape", "verify_plan", "verify_trace_plan",
    "verify_served_plan", "verify_shared_plan", "verify_window_choice",
    "verify_snapshot",
    "verify_timeline", "verify_degraded", "verify_recovery",
    "clear_verifier_caches",
    "certify_lane", "certify_trace_lane", "certify_batch",
    "certify_trace_batch", "clear_certifier_cache", "partition_backends",
]
