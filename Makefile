PYTHONPATH := src

.PHONY: test bench bench-smoke plan-bench sweep lint

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# Full paper-figure benchmark CSV.
bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

# Tiny generalized schedule sweep: catches benchmark/scheduler rot in CI.
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --sweep --smoke

# Candidate-set planning timings + DP relaxation counts at n in {96, 384}:
# all-R single-pass DP vs the legacy per-R loop, recorded to BENCH_planner.json.
plan-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.planner_bench --json BENCH_planner.json

# Full n x r x m sweep, recorded for the perf trajectory.
sweep:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --sweep --json BENCH_bridge_radix.json

lint:
	ruff check --select E9,F63,F7,F82 src tests benchmarks examples
