PYTHONPATH := src

# Pinned coverage-gate floor for repro/{core,planner,workloads}; measured at
# ~95% on the tier-1 suite, pinned with head-room (see benchmarks/coverage_gate).
COV_MIN ?= 84

.PHONY: test test-fast bench bench-smoke plan-bench fabric-bench sim-bench \
	trace-bench online-bench faults-bench tenancy-bench sweep coverage \
	lint verify-gate docs-gate

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# Tier-1 CI subset: everything not marked slow.
test-fast:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q -m "not slow"

# Full paper-figure benchmark CSV.
bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

# Tiny generalized schedule sweep: catches benchmark/scheduler rot in CI.
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --sweep --smoke

# Candidate-set planning timings + DP relaxation counts at n in {96, 384}:
# all-R single-pass DP vs the legacy per-R loop, recorded to BENCH_planner.json.
plan-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.planner_bench --json BENCH_planner.json

# Sparse vs full-pause vs analytic completion times on the asynchronous
# per-link fabric (FabricSim) over the n x r x delta grid, with the
# event/analytic ratio and sparse-margin gates; recorded to
# BENCH_fabric_overlap.json.
fabric-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.fabric_bench --json BENCH_fabric_overlap.json

# Scalar sparse FabricSim vs the vectorized batch engine (core.batchsim)
# vs the JAX jit/vmap backend (core.batchsim_jax): 30+-candidate
# event-scoring batch at n=96 (gated >= 10x), batched-only n in {768, 1536}
# scale rows, the NumPy-vs-JAX differential tier at n=1536/256 lanes (gated
# >= 3x, bit-stable, <= 1e-6), JAX-only n in {8192, 32768} rows, and LRU
# plan-cache hit rates; recorded to BENCH_sim_scale.json.
sim-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.sim_bench --json BENCH_sim_scale.json

# Cross-collective trace planning: carryover vs cold-fabric vs static over
# workload traces (MoE a2a / gradient AR / decode AG / mixed) x n x delta,
# gated carryover <= cold everywhere + a minimum amortization win at
# ms-scale delta; recorded to BENCH_trace.json.
trace-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.trace_bench --json BENCH_trace.json

# Online receding-horizon planning vs the offline DP vs cold per-event over
# traces x n x delta x window W (regret gates: never better than offline,
# within --max-regret for W >= 2, beats cold at ms-scale delta), plus the
# plan-serving request storm (cache-hit plans/sec floor); recorded to
# BENCH_online.json.
online-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.online_bench --json BENCH_online.json

# Fault injection + degraded-mode recovery over fault kind x n x delta x
# failure time (gates: resume-from-snapshot <= restart-from-scratch on every
# row, recovered result bit-identical to a clean reduced-world run);
# recorded to BENCH_faults.json.
faults-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.faults_bench --json BENCH_faults.json

# Multi-tenant fabric sharing: port-partitioned and time-sliced shared
# planning vs naive serialization over K x n x delta x sharing mode (gates:
# shared <= serialized on both metrics everywhere, per-tenant isolation
# within its structural bound, perfect port-partition isolation); recorded
# to BENCH_tenancy.json.
tenancy-bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.tenancy_bench --json BENCH_tenancy.json

# Full n x r x m sweep, recorded for the perf trajectory.
sweep:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --sweep --json BENCH_bridge_radix.json

# Line coverage over the planning stack (pytest-cov), gated at COV_MIN% for
# repro/{core,planner,workloads} by benchmarks/coverage_gate.
coverage:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q -m "not slow" \
		--cov=repro --cov-report=xml --cov-report=term
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.coverage_gate coverage.xml --min $(COV_MIN)

# Static audit of every plan the committed BENCH_*.json baselines imply
# (repro.analysis verifier + fast-path certificate coverage); exit 1 on any
# violation.
verify-gate:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.verify_gate

# Docs honesty gate: every relative link in README/docs resolves, and every
# fenced python block in docs/batch_engine.md executes (doctest-style).
docs-gate:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.docs_gate

lint:
	ruff check --select E,F,W,I,B,C4 src tests benchmarks examples
